//! Deterministic virtual-time simulation: the paper's asymptotics at `K`
//! in the thousands, in one process, on one wall-clock-free timeline.
//!
//! [`run_sim`] drives `K` [`WorkerCore`]s — the exact per-worker phase
//! machine behind every other driver — over a frame-stepped virtual
//! clock. All data really flows: frames are encoded, serialized,
//! delivered in arrival order, decoded, and folded bit-for-bit, so the
//! final state is bit-identical to the engine on the same job. Only
//! *time* is simulated:
//!
//! * each worker's compute phases are priced by the [`TimeModel`] over
//!   the [`PreparedJob`]'s per-worker work tallies — the same tables
//!   the engine's modeled times fold, straggler-scaled first;
//! * every staged frame pays NIC serialization (`len / bandwidth`) on
//!   the sender's virtual cursor plus a one-way link latency, and one
//!   serialization covers every receiver of a *multicast* — exactly
//!   the saving the coded scheme banks on;
//! * seeded per-worker straggler draws ([`DetRng`] split streams, one
//!   stream per worker so draws are independent of any other worker's
//!   fate) stretch compute phases by a configurable slowdown.
//!
//! The flight-recorder spans ([`crate::obs`]) carry *virtual*
//! timestamps (the cores run with wall-clock tracing off; the driver
//! re-records each phase window via [`WorkerCore::note_span`]), so two
//! runs with the same [`SimConfig::seed`] are bit-identical in results,
//! loads, iteration records, **and** span timelines.
//!
//! Failure injection replays the cluster's degraded mode (PR 6) at
//! scales the TCP driver cannot reach: a dead worker's coded groups
//! collapse to raw donor rows, its uncoded transfers are re-covered by
//! surviving batch replicas, and its ghost core lands on one adopter
//! chosen by a [`RecoveryPolicy`] — the placement knob this module
//! exists to compare at large `K`.

use crate::graph::csr::Vertex;
use crate::obs::{Phase, TraceSpan};
use crate::shuffle::load::ShuffleLoad;
use crate::transport::frame::Frame;
use crate::util::rng::DetRng;
use crate::WorkerId;

use super::config::{FailWorker, Scheme, TimeModel};
use super::engine::{prepare, prepare_worker, Job, PreparedJob, PreparedWorker};
use super::exec::{stage_dead_sender_transfers, Fabric, WorkerCore};
use super::metrics::RecoveryStats;

// The ghost-placement policy moved to `config` when the cluster driver
// grew the same knob (`--policy` works on `cluster` and `simulate`
// alike); re-exported here so sim-facing callers keep their import path.
pub use super::config::RecoveryPolicy;

/// The straggler *service-time* model: how much slower a straggling
/// worker's compute phases run this iteration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StragglerDist {
    /// With probability [`SimConfig::straggler_prob`] a worker's phases
    /// stretch by exactly [`SimConfig::straggler_slowdown`]; otherwise
    /// they run at speed 1. The paper's two-point model.
    #[default]
    Bernoulli,
    /// Every worker draws a lognormal multiplier
    /// `exp(sigma * N(0,1)).max(1)` with
    /// `sigma = ln(straggler_slowdown.max(1))`, so the configured
    /// slowdown becomes the one-sigma stretch instead of a hard mode —
    /// the heavy-tailed service times measured on real clusters.
    /// `straggler_prob` is ignored; the tail is always on.
    Lognormal,
}

impl StragglerDist {
    /// The stable CLI token.
    pub fn token(&self) -> &'static str {
        match self {
            StragglerDist::Bernoulli => "bernoulli",
            StragglerDist::Lognormal => "lognormal",
        }
    }
}

impl std::str::FromStr for StragglerDist {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "bernoulli" => Ok(StragglerDist::Bernoulli),
            "lognormal" => Ok(StragglerDist::Lognormal),
            other => Err(format!(
                "unknown straggler distribution {other:?} (expected bernoulli|lognormal)"
            )),
        }
    }
}

impl std::fmt::Display for StragglerDist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.token())
    }
}

/// Virtual cluster parameters: link model, straggler model, failure
/// injection. Defaults approximate the paper's testbed (100 Mbps NIC,
/// sub-millisecond LAN latency, Python-speed compute).
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Root seed for every stochastic choice (stragglers). Two runs
    /// with equal seeds are bit-identical end to end.
    pub seed: u64,
    /// One-way link latency in virtual nanoseconds.
    pub latency_ns: u64,
    /// Per-NIC serialization bandwidth, bits per second.
    pub bandwidth_bps: f64,
    /// Per-(worker, iteration) probability of straggling (the
    /// [`StragglerDist::Bernoulli`] model; ignored by `Lognormal`).
    pub straggler_prob: f64,
    /// Compute-time multiplier applied to a straggling worker (>= 1).
    /// Under [`StragglerDist::Lognormal`] this sets the one-sigma
    /// stretch: `sigma = ln(straggler_slowdown)`.
    pub straggler_slowdown: f64,
    /// Shape of the straggler service-time draw.
    pub straggler_dist: StragglerDist,
    /// Per-operation compute-time model.
    pub time: TimeModel,
    /// Up to two workers that die at the top of a given iteration
    /// (the cluster drivers' `--fail-worker` shape).
    pub fail_workers: [Option<FailWorker>; 2],
    /// Ghost-placement policy after a failure.
    pub policy: RecoveryPolicy,
    /// Model the pipelined fabric (PR 10, `simulate --fabric
    /// pipelined`): the worker thread hands its staged frames to a
    /// writer loop at the end of encode, so it is ready to ingest at
    /// *encode* end instead of *serialization* end — NIC wire time
    /// overlaps recv-wait. Arrival times are unchanged (the NIC still
    /// serializes every frame before it travels), so results, loads,
    /// and wire tallies are bit-identical to the sync model; only the
    /// virtual timeline compresses. This is the `sim-sweep`-scale
    /// predictor for the TCP fabric's measured overlap win.
    pub pipelined: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 2018,
            latency_ns: 500_000,
            bandwidth_bps: 100e6,
            straggler_prob: 0.0,
            straggler_slowdown: 4.0,
            straggler_dist: StragglerDist::Bernoulli,
            time: TimeModel::python_speed(),
            fail_workers: [None, None],
            policy: RecoveryPolicy::LowestSurvivor,
            pipelined: false,
        }
    }
}

/// One simulated iteration's record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimIterRecord {
    /// Virtual start time of the iteration (the BSP barrier).
    pub start_ns: u64,
    /// Virtual makespan: the slowest worker's finish minus `start_ns`.
    pub makespan_ns: u64,
    /// Wire frames staged this iteration (loopback excluded).
    pub wire_frames: u64,
    /// Wire bytes staged this iteration (headers included).
    pub wire_bytes: u64,
    /// Recovery generation the iteration ran under.
    pub epoch: u8,
}

/// Everything a simulation run produces.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Final state after the last iteration (bit-identical to the
    /// engine on the same job).
    pub final_state: Vec<f64>,
    /// Per-iteration virtual-time and wire records.
    pub iterations: Vec<SimIterRecord>,
    /// One *healthy* iteration's shuffle load from the deterministic
    /// accounting replay (paper units; state-independent). The sim
    /// asserts its staged wire tallies against this on every
    /// failure-free iteration — the engine's model ≡ staged invariant.
    pub clean_load: ShuffleLoad,
    /// Flight-recorder spans with virtual timestamps, drained at job
    /// end (cores ascending, then ghost cores).
    pub spans: Vec<TraceSpan>,
    /// Degraded-mode accounting (defaults for a clean run).
    pub recovery: RecoveryStats,
    /// Total virtual time of the job.
    pub total_ns: u64,
}

impl SimReport {
    /// Total virtual seconds.
    pub fn total_virtual_s(&self) -> f64 {
        self.total_ns as f64 * 1e-9
    }

    /// FNV-1a digest over the final state's bit patterns — a compact
    /// determinism witness for CLI output and tests.
    pub fn state_digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for s in &self.final_state {
            h = (h ^ s.to_bits()).wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

/// The deterministic accounting replay of one healthy iteration's
/// shuffle — identical to the engine's (canonical group/transfer order),
/// shared so the sim's loads and the theory-validation tests measure
/// exactly what the engine would.
pub fn clean_iteration_load(prep: &PreparedJob) -> ShuffleLoad {
    let mut load = ShuffleLoad::default();
    match prep.scheme {
        Scheme::Uncoded | Scheme::UncodedCombined => {
            for t in &prep.transfers {
                load.add_uncoded(t.ivs.len());
            }
        }
        Scheme::Coded | Scheme::CodedCombined => {
            let r = prep.plan.members() - 1;
            for gi in 0..prep.plan.num_groups() {
                for &q in prep.plan.sender_cols(gi) {
                    if q > 0 {
                        load.add_coded(q as usize, r);
                    }
                }
            }
        }
    }
    load
}

/// Deterministic f64-seconds → virtual-ns conversion.
#[inline]
fn ns(secs: f64) -> u64 {
    (secs * 1e9).round() as u64
}

/// One staged frame in flight: arrival time, a global staging-order
/// tie-break, and its byte range in the iteration arena.
#[derive(Clone, Copy)]
struct Msg {
    arrival_ns: u64,
    seq: u64,
    start: u32,
    end: u32,
}

/// Per-iteration frame store: one flat byte arena (all senders append
/// serially) plus per-receiver inboxes sorted by `(arrival, seq)`
/// before ingest — virtual-time delivery order, fully deterministic.
#[derive(Default)]
struct SimNet {
    arena: Vec<u8>,
    inboxes: Vec<Vec<Msg>>,
    seq: u64,
}

impl SimNet {
    fn begin_iteration(&mut self, k: usize) {
        if self.inboxes.len() != k {
            self.inboxes = (0..k).map(|_| Vec::new()).collect();
        }
        for ib in &mut self.inboxes {
            ib.clear();
        }
        self.arena.clear();
        self.seq = 0;
    }

    fn push(&mut self, to: WorkerId, arrival_ns: u64, start: u32, end: u32) {
        let seq = self.seq;
        self.seq += 1;
        self.inboxes[to as usize].push(Msg { arrival_ns, seq, start, end });
    }

    fn sort_inbox(&mut self, k: usize) {
        self.inboxes[k].sort_unstable_by_key(|m| (m.arrival_ns, m.seq));
    }
}

/// The staging half: one worker's NIC during the stage phase. The
/// cursor starts where the worker's (straggler-scaled) Map + Encode
/// compute ends; each staged frame advances it by the frame's
/// serialization time, and receivers see the frame one link latency
/// after serialization completes. Self-addressed frames (an adopter
/// acting as its own ghost's donor) cross no wire: delivered at the
/// current cursor, untallied — the same rule every other fabric applies.
struct SimSender<'a> {
    net: &'a mut SimNet,
    me: WorkerId,
    cursor_ns: u64,
    latency_ns: u64,
    ns_per_byte: f64,
    staged_frames: u32,
    staged_bytes: u64,
}

impl Fabric for SimSender<'_> {
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]) {
        let start = self.net.arena.len() as u32;
        self.net.arena.extend_from_slice(frame);
        let end = self.net.arena.len() as u32;
        self.cursor_ns += (frame.len() as f64 * self.ns_per_byte).round() as u64;
        let arrival = self.cursor_ns + self.latency_ns;
        for &to in receivers {
            self.net.push(to, arrival, start, end);
        }
        self.staged_frames += 1;
        self.staged_bytes += frame.len() as u64;
    }

    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]) {
        if to == self.me {
            let start = self.net.arena.len() as u32;
            self.net.arena.extend_from_slice(frame);
            let end = self.net.arena.len() as u32;
            self.net.push(to, self.cursor_ns, start, end);
            return;
        }
        self.stage_multicast(std::slice::from_ref(&to), frame);
    }

    fn complete_sends(&mut self, frames: u32, bytes: u64) {
        // the core's own tally (donor extras folded in, loopback
        // excluded) must equal what actually crossed the virtual wire
        assert_eq!(
            (frames, bytes),
            (self.staged_frames, self.staged_bytes),
            "sim: worker {} staged tally disagrees with the core's accounting",
            self.me
        );
    }

    fn recv_data(&mut self, _buf: &mut Vec<u8>) -> bool {
        unreachable!("sim: the stage phase has no inbound frames")
    }
}

/// The ingest half: a cursor over one worker's arrival-sorted inbox.
struct SimReceiver<'a> {
    net: &'a SimNet,
    me: usize,
    pos: usize,
    last_arrival_ns: u64,
}

impl SimReceiver<'_> {
    fn drained(&self) -> bool {
        self.pos >= self.net.inboxes[self.me].len()
    }
}

impl Fabric for SimReceiver<'_> {
    fn stage_multicast(&mut self, _receivers: &[WorkerId], _frame: &[u8]) {
        unreachable!("sim: the ingest phase stages nothing")
    }

    fn stage_unicast(&mut self, _to: WorkerId, _frame: &[u8]) {
        unreachable!("sim: the ingest phase stages nothing")
    }

    fn complete_sends(&mut self, _frames: u32, _bytes: u64) {
        unreachable!("sim: the ingest phase stages nothing")
    }

    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool {
        let inbox = &self.net.inboxes[self.me];
        if self.pos >= inbox.len() {
            return false;
        }
        let m = inbox[self.pos];
        self.pos += 1;
        self.last_arrival_ns = self.last_arrival_ns.max(m.arrival_ns);
        buf.clear();
        buf.extend_from_slice(&self.net.arena[m.start as usize..m.end as usize]);
        true
    }
}

/// How many multicast groups plus uncoded transfers `dead` degrades —
/// the traffic the recovery re-plans onto surviving replicas.
fn count_recovered(prep: &PreparedJob, dead: &[WorkerId]) -> usize {
    let mut n = 0usize;
    for gi in 0..prep.plan.num_groups() {
        if prep.plan.group(gi).servers.iter().any(|s| dead.contains(s)) {
            n += 1;
        }
    }
    n + prep.transfers.iter().filter(|t| dead.contains(&t.sender)).count()
}

/// Run `iters` iterations of `job` under `scheme` on the virtual-time
/// fabric. Results are bit-identical to the engine; time, spans, and
/// failure recovery follow [`SimConfig`]. Serial by construction — the
/// virtual clock, not the host's core count, orders every event.
pub fn run_sim(job: &Job<'_>, scheme: Scheme, iters: usize, cfg: &SimConfig) -> SimReport {
    let (g, alloc, prog) = (job.graph, job.alloc, job.program);
    let n = g.n();
    let k = alloc.k;
    assert!(k >= 2 && k < WorkerId::MAX as usize, "sim: K = {k} out of range");
    assert!(cfg.straggler_slowdown >= 1.0, "sim: slowdown must be >= 1");
    let prep = prepare(job, scheme);
    let clean_load = clean_iteration_load(&prep);
    let ns_per_byte = 8e9 / cfg.bandwidth_bps;

    // one straggler stream per worker: a worker's draws never depend on
    // any other worker's fate, so policy comparisons replay identical
    // straggler weather
    let mut root = DetRng::seed(cfg.seed);
    let mut wrng: Vec<DetRng> = (0..k).map(|w| root.split(w as u64)).collect();

    let mut cores: Vec<Option<WorkerCore>> = (0..k)
        .map(|kk| Some(WorkerCore::new(job, prepare_worker(job, scheme, kk as WorkerId))))
        .collect();
    // wall-clock tracing stays off; the driver records virtual spans
    for c in cores.iter_mut().flatten() {
        c.set_trace(false);
    }
    let mut ghosts: Vec<WorkerCore> = Vec::new();
    let mut ghost_preps: Vec<PreparedWorker> = Vec::new();
    let mut dead: Vec<WorkerId> = Vec::new();
    let mut route: Vec<WorkerId> = (0..k as WorkerId).collect();
    let mut adopter: WorkerId = 0;
    let mut epoch = 0u8;
    let mut recovery = RecoveryStats::default();

    let mut state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, g)).collect();
    let mut next = vec![0.0f64; n];
    let mut net = SimNet::default();
    let mut records: Vec<SimIterRecord> = Vec::with_capacity(iters);
    let mut rbuf: Vec<u8> = Vec::new();
    let mut t = 0u64;

    for it in 0..iters {
        // ---- failure injection at the top of the iteration ------------
        let newly: Vec<WorkerId> = cfg
            .fail_workers
            .iter()
            .flatten()
            .filter(|f| f.at_iter == it)
            .map(|f| f.worker)
            .collect();
        if !newly.is_empty() {
            for &w in &newly {
                assert!(
                    (w as usize) < k && !dead.contains(&w),
                    "sim: bad fail spec {w}@{it}"
                );
                dead.push(w);
            }
            dead.sort_unstable();
            assert!(
                dead.len() < alloc.r.max(1),
                "sim: {} failures exceed the plan's r - 1 = {} tolerance",
                dead.len(),
                alloc.r.saturating_sub(1)
            );
            epoch += 1;
            let survivors: Vec<WorkerId> =
                (0..k as WorkerId).filter(|w| !dead.contains(w)).collect();
            adopter = match cfg.policy {
                RecoveryPolicy::LowestSurvivor => survivors[0],
                RecoveryPolicy::LoadSpread => survivors
                    .iter()
                    .copied()
                    .min_by_key(|&w| {
                        prep.mapped_edges[w as usize] + prep.reduce_edges[w as usize]
                    })
                    .expect("sim: no survivors"),
            };
            for (w, hop) in route.iter_mut().enumerate() {
                *hop = if dead.contains(&(w as WorkerId)) { adopter } else { w as WorkerId };
            }
            for &w in &newly {
                cores[w as usize] = None;
                ghost_preps.push(prepare_worker(job, scheme, w));
                let mut gc = WorkerCore::new(job, prepare_worker(job, scheme, w));
                gc.set_trace(false);
                ghosts.push(gc);
            }
            for c in cores.iter_mut().flatten() {
                c.adopt_with(job, &dead, epoch, adopter);
            }
            for gc in ghosts.iter_mut() {
                gc.adopt_with(job, &dead, epoch, adopter);
            }
            recovery.failures = dead.len();
            recovery.recovered_groups = count_recovered(&prep, &dead);
        }

        // ---- stage phase: encode + serialize on every live NIC --------
        net.begin_iteration(k);
        let mut straggle = vec![1.0f64; k];
        let mut send_end = vec![t; k];
        let mut enc_end = vec![t; k];
        let mut wire_frames = 0u64;
        let mut wire_bytes = 0u64;
        for w in 0..k {
            let Some(core) = cores[w].as_mut() else { continue };
            let s = match cfg.straggler_dist {
                StragglerDist::Bernoulli => {
                    if wrng[w].bernoulli(cfg.straggler_prob) {
                        cfg.straggler_slowdown
                    } else {
                        1.0
                    }
                }
                StragglerDist::Lognormal => {
                    // sigma = ln(slowdown): the configured slowdown is the
                    // one-sigma stretch; clamp at 1 — stragglers are only
                    // ever slow, matching the Bernoulli model's floor
                    let sigma = cfg.straggler_slowdown.max(1.0).ln();
                    (sigma * wrng[w].normal()).exp().max(1.0)
                }
            };
            straggle[w] = s;
            let enc_ns = ns(
                (prep.mapped_edges[w] as f64 * cfg.time.map_edge_s
                    + prep.encode_bytes()[w] as f64 * cfg.time.encode_byte_s)
                    * s,
            );
            let mut sender = SimSender {
                net: &mut net,
                me: w as WorkerId,
                cursor_ns: t + enc_ns,
                latency_ns: cfg.latency_ns,
                ns_per_byte,
                staged_frames: 0,
                staged_bytes: 0,
            };
            let mut extra = (0u32, 0u64);
            for gp in &ghost_preps {
                let (f, b) = stage_dead_sender_transfers(
                    job,
                    gp,
                    &dead,
                    w as WorkerId,
                    &route,
                    &state,
                    epoch,
                    &mut sender,
                );
                extra.0 += f;
                extra.1 += b;
            }
            core.stage_sends_with_extra(job, &state, &mut sender, extra);
            send_end[w] = sender.cursor_ns;
            enc_end[w] = t + enc_ns;
            wire_frames += sender.staged_frames as u64;
            wire_bytes += sender.staged_bytes;
            let stage_ns = send_end[w] - (t + enc_ns);
            let (sb, sf) = (sender.staged_bytes, sender.staged_frames);
            core.set_trace(true);
            core.set_trace_iter(it as u32);
            core.note_span(Phase::Encode, t, enc_ns, 0, 0);
            if cfg.pipelined {
                // the hand-off itself is free on the worker's timeline;
                // the NIC serializes [enc_end, send_end] in the
                // background, surfacing as the receivers' arrivals
                core.note_span(Phase::FlushWait, t + enc_ns, 0, sb, sf);
            } else {
                core.note_span(Phase::Stage, t + enc_ns, stage_ns, sb, sf);
            }
            core.set_trace(false);
        }

        // model ≡ staged: on a failure-free iteration the cores must
        // stage exactly what the accounting replay charges (the same
        // invariant the engine and the cluster leader assert)
        if dead.is_empty() {
            assert_eq!(
                wire_frames as usize, clean_load.messages,
                "sim staged a different frame count than the accounting modeled"
            );
            assert_eq!(
                wire_bytes as usize,
                clean_load.wire_bytes_with_headers(),
                "sim staged different wire bytes than the accounting modeled"
            );
        }

        // ---- ingest → decode → fold in virtual arrival order ----------
        let mut done_ns = vec![t; k];
        let mut ghost_windows: Vec<(u64, u64, u64)> = Vec::new();
        for w in 0..k {
            if cores[w].is_none() {
                continue;
            }
            net.sort_inbox(w);
            let hosts_ghosts = w as WorkerId == adopter && !ghosts.is_empty();
            let mut rx = SimReceiver { net: &net, me: w, pos: 0, last_arrival_ns: 0 };
            let core = cores[w].as_mut().expect("live core");
            while !(core.data_complete()
                && (!hosts_ghosts || ghosts.iter().all(WorkerCore::data_complete)))
            {
                assert!(rx.recv_data(&mut rbuf), "sim: worker {w} starved mid-shuffle");
                let f = Frame::parse(&rbuf).expect("sim: bad frame");
                let taken = core.try_ingest(&f)
                    || (hosts_ghosts && ghosts.iter_mut().any(|gc| gc.try_ingest(&f)));
                assert!(taken, "sim: unroutable {:?} frame at worker {w}", f.kind);
            }
            assert!(rx.drained(), "sim: leftover frames at worker {w}");
            core.reset_ingest();
            core.decode_and_fold(job, &state, None);
            for (slot, &i) in alloc.reduce_sets[w].iter().enumerate() {
                next[i as usize] = f64::from_bits(core.next_bits()[slot]);
            }
            // sync: the worker thread is busy writing its NIC until
            // send_end. Pipelined: the writer thread owns the NIC, so
            // the worker turns to ingest right after encode — wire time
            // hides behind the arrivals it still has to wait for.
            let ready_base = if cfg.pipelined { enc_end[w] } else { send_end[w] };
            let ready = ready_base.max(rx.last_arrival_ns);
            let dec_ns =
                ns(prep.decode_bytes()[w] as f64 * cfg.time.decode_byte_s * straggle[w]);
            let red_ns =
                ns(prep.reduce_edges[w] as f64 * cfg.time.reduce_iv_s * straggle[w]);
            core.set_trace(true);
            core.note_span(Phase::RecvWait, ready_base, ready - ready_base, 0, 0);
            core.note_span(Phase::Decode, ready, dec_ns, 0, 0);
            core.note_span(Phase::Fold, ready + dec_ns, red_ns, 0, core.last_validated());
            core.set_trace(false);
            let mut cursor = ready + dec_ns + red_ns;
            if hosts_ghosts {
                // adopted ghost work runs after the adopter's own, on
                // the same physical timeline (windows in ghost order)
                for gc in ghosts.iter() {
                    let gw = gc.me() as usize;
                    let gdec = ns(
                        prep.decode_bytes()[gw] as f64
                            * cfg.time.decode_byte_s
                            * straggle[w],
                    );
                    let gred = ns(
                        prep.reduce_edges[gw] as f64
                            * cfg.time.reduce_iv_s
                            * straggle[w],
                    );
                    ghost_windows.push((cursor, gdec, gred));
                    cursor += gdec + gred;
                }
            }
            done_ns[w] = cursor;
        }
        for (gi, gc) in ghosts.iter_mut().enumerate() {
            gc.reset_ingest();
            gc.refresh_local_cache(job, &state);
            gc.decode_and_fold(job, &state, None);
            for (slot, &i) in alloc.reduce_sets[gc.me() as usize].iter().enumerate() {
                next[i as usize] = f64::from_bits(gc.next_bits()[slot]);
            }
            let (start, gdec, gred) = ghost_windows[gi];
            gc.set_trace(true);
            gc.set_trace_iter(it as u32);
            gc.note_span(Phase::Decode, start, gdec, 0, 0);
            gc.note_span(Phase::Fold, start + gdec, gred, 0, gc.last_validated());
            gc.set_trace(false);
        }

        let end = (0..k)
            .filter(|&w| cores[w].is_some())
            .map(|w| done_ns[w])
            .max()
            .unwrap_or(t);
        records.push(SimIterRecord {
            start_ns: t,
            makespan_ns: end - t,
            wire_frames,
            wire_bytes,
            epoch,
        });
        t = end;
        std::mem::swap(&mut state, &mut next);
    }

    // load inflation: actual wire bytes (failed epochs' donor rows and
    // recovery pairs included) over the clean model's, minus one —
    // exactly 0.0 for a clean run by the model ≡ staged assert above
    let clean_bytes = clean_load.wire_bytes_with_headers() as f64 * iters as f64;
    if clean_bytes > 0.0 {
        let actual: f64 = records.iter().map(|rec| rec.wire_bytes as f64).sum();
        recovery.load_inflation = actual / clean_bytes - 1.0;
    }

    let mut spans = Vec::new();
    for c in cores.iter_mut().flatten() {
        let me = c.me();
        c.drain_spans(me, &mut spans);
    }
    for gc in ghosts.iter_mut() {
        gc.drain_spans(adopter, &mut spans);
    }

    SimReport {
        final_state: state,
        iterations: records,
        clean_load,
        spans,
        recovery,
        total_ns: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Allocation;
    use crate::coordinator::config::EngineConfig;
    use crate::coordinator::engine::run_rust;
    use crate::graph::er::er;
    use crate::mapreduce::program::run_single_machine;
    use crate::mapreduce::PageRank;

    fn sim_cfg(seed: u64) -> SimConfig {
        SimConfig { seed, straggler_prob: 0.3, ..Default::default() }
    }

    #[test]
    fn same_seed_runs_are_bit_identical() {
        let g = er(160, 0.1, &mut DetRng::seed(61));
        let alloc = Allocation::cyclic_scheme(160, 8, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let a = run_sim(&job, Scheme::Coded, 3, &sim_cfg(7));
        let b = run_sim(&job, Scheme::Coded, 3, &sim_cfg(7));
        let bits = |r: &SimReport| r.final_state.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.spans, b.spans, "span timelines must replay exactly");
        assert_eq!(a.state_digest(), b.state_digest());
        assert!(a.total_ns > 0);
        assert!(!a.spans.is_empty());
    }

    #[test]
    fn different_seeds_move_the_timeline_not_the_results() {
        let g = er(160, 0.1, &mut DetRng::seed(61));
        let alloc = Allocation::cyclic_scheme(160, 8, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let a = run_sim(&job, Scheme::Coded, 3, &sim_cfg(7));
        let b = run_sim(&job, Scheme::Coded, 3, &sim_cfg(8));
        for (x, y) in a.final_state.iter().zip(&b.final_state) {
            assert_eq!(x.to_bits(), y.to_bits(), "stragglers must not change results");
        }
        assert_ne!(
            a.iterations, b.iterations,
            "different straggler draws should move the virtual timeline"
        );
    }

    #[test]
    fn sim_matches_engine_bit_for_bit() {
        let g = er(150, 0.12, &mut DetRng::seed(62));
        let alloc = Allocation::er_scheme(150, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        for scheme in [Scheme::Coded, Scheme::Uncoded, Scheme::CodedCombined] {
            let sim = run_sim(&job, scheme, 4, &SimConfig::default());
            let eng = run_rust(&job, &EngineConfig { scheme, ..Default::default() }, 4);
            for (a, b) in sim.final_state.iter().zip(&eng.final_state) {
                assert_eq!(a.to_bits(), b.to_bits(), "{scheme}: sim diverged from engine");
            }
            // absolute anchor
            let want = run_single_machine(&prog, &g, 4);
            for (a, b) in sim.final_state.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12, "{scheme}: {a} vs {b}");
            }
            // load replay matches the engine's accounting
            assert_eq!(
                sim.clean_load.paper_bits.to_bits(),
                eng.iterations[0].shuffle.paper_bits.to_bits(),
                "{scheme}"
            );
            assert_eq!(sim.recovery.load_inflation, 0.0, "{scheme}: clean run inflates");
        }
    }

    #[test]
    fn failure_replay_recovers_bit_identically_under_both_policies() {
        let g = er(120, 0.15, &mut DetRng::seed(63));
        let alloc = Allocation::er_scheme(120, 5, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let clean = run_sim(&job, Scheme::Coded, 3, &SimConfig::default());
        for policy in [RecoveryPolicy::LowestSurvivor, RecoveryPolicy::LoadSpread] {
            let cfg = SimConfig {
                fail_workers: [Some(FailWorker { worker: 1, at_iter: 1 }), None],
                policy,
                ..Default::default()
            };
            let failed = run_sim(&job, Scheme::Coded, 3, &cfg);
            for (a, b) in clean.final_state.iter().zip(&failed.final_state) {
                assert_eq!(a.to_bits(), b.to_bits(), "{policy}: recovery changed results");
            }
            assert_eq!(failed.recovery.failures, 1);
            assert!(failed.recovery.recovered_groups > 0, "{policy}");
            assert!(
                failed.recovery.load_inflation > 0.0,
                "{policy}: raw donor rows must cost wire bytes"
            );
            // ghost spans ride the adopter's physical timeline
            let ghost_spans =
                failed.spans.iter().filter(|s| s.core == 1 && s.epoch > 0).count();
            assert!(ghost_spans > 0, "{policy}: ghost core left no spans");
        }
    }

    #[test]
    fn two_failures_within_tolerance_recover() {
        let g = er(100, 0.15, &mut DetRng::seed(64));
        let alloc = Allocation::er_scheme(100, 5, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let clean = run_sim(&job, Scheme::Uncoded, 3, &SimConfig::default());
        let cfg = SimConfig {
            fail_workers: [
                Some(FailWorker { worker: 1, at_iter: 1 }),
                Some(FailWorker { worker: 3, at_iter: 2 }),
            ],
            ..Default::default()
        };
        let failed = run_sim(&job, Scheme::Uncoded, 3, &cfg);
        for (a, b) in clean.final_state.iter().zip(&failed.final_state) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(failed.recovery.failures, 2);
        assert_eq!(failed.iterations[0].epoch, 0);
        assert_eq!(failed.iterations[1].epoch, 1);
        assert_eq!(failed.iterations[2].epoch, 2);
    }

    #[test]
    fn lognormal_stragglers_are_deterministic_and_result_neutral() {
        let g = er(160, 0.1, &mut DetRng::seed(66));
        let alloc = Allocation::cyclic_scheme(160, 8, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let cfg = SimConfig {
            seed: 19,
            straggler_dist: StragglerDist::Lognormal,
            straggler_slowdown: 6.0,
            ..Default::default()
        };
        let a = run_sim(&job, Scheme::Coded, 3, &cfg);
        let b = run_sim(&job, Scheme::Coded, 3, &cfg);
        assert_eq!(a.iterations, b.iterations, "same seed must replay the same tail");
        assert_eq!(a.state_digest(), b.state_digest());
        // service-time noise moves the clock, never the values
        let calm = run_sim(&job, Scheme::Coded, 3, &SimConfig::default());
        for (x, y) in a.final_state.iter().zip(&calm.final_state) {
            assert_eq!(x.to_bits(), y.to_bits(), "lognormal tail changed results");
        }
        // a heavy tail over 8 workers x 3 iterations all but surely
        // stretches at least one phase (P[all 24 draws <= 0] = 2^-24)
        assert!(
            a.total_ns > calm.total_ns,
            "lognormal multipliers should stretch the virtual makespan"
        );
    }

    #[test]
    fn pipelined_model_compresses_time_not_results() {
        let g = er(160, 0.1, &mut DetRng::seed(67));
        let alloc = Allocation::cyclic_scheme(160, 8, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let sync = run_sim(&job, Scheme::Coded, 3, &SimConfig::default());
        let pipe = run_sim(
            &job,
            Scheme::Coded,
            3,
            &SimConfig { pipelined: true, ..Default::default() },
        );
        // results, loads, and wire tallies are untouched by the overlap
        assert_eq!(sync.state_digest(), pipe.state_digest());
        for (a, b) in sync.iterations.iter().zip(&pipe.iterations) {
            assert_eq!(a.wire_frames, b.wire_frames);
            assert_eq!(a.wire_bytes, b.wire_bytes);
        }
        // hiding NIC serialization behind recv-wait can only shorten
        // the virtual makespan (equality would mean zero wire time)
        assert!(
            pipe.total_ns <= sync.total_ns,
            "pipelined model must never be slower than sync"
        );
        assert!(
            pipe.total_ns < sync.total_ns,
            "a 100 Mbps NIC leaves wire time to hide; the overlap must show"
        );
        // determinism holds with the overlap model on
        let again = run_sim(
            &job,
            Scheme::Coded,
            3,
            &SimConfig { pipelined: true, ..Default::default() },
        );
        assert_eq!(pipe.spans, again.spans);
        // the pipelined timeline attributes hand-off as FlushWait
        assert!(
            pipe.spans.iter().any(|s| s.phase == Phase::FlushWait),
            "pipelined sim must mark the hand-off"
        );
        assert!(
            sync.spans.iter().all(|s| s.phase != Phase::FlushWait),
            "sync sim must not"
        );
    }

    #[test]
    fn straggler_dist_tokens_roundtrip() {
        for d in [StragglerDist::Bernoulli, StragglerDist::Lognormal] {
            assert_eq!(d.token().parse::<StragglerDist>().unwrap(), d);
        }
        assert!("pareto".parse::<StragglerDist>().is_err());
    }

    #[test]
    fn policy_tokens_roundtrip() {
        for p in [RecoveryPolicy::LowestSurvivor, RecoveryPolicy::LoadSpread] {
            assert_eq!(p.token().parse::<RecoveryPolicy>().unwrap(), p);
        }
        assert!("sideways".parse::<RecoveryPolicy>().is_err());
    }

    #[test]
    fn stragglers_stretch_the_makespan() {
        let g = er(160, 0.1, &mut DetRng::seed(65));
        let alloc = Allocation::cyclic_scheme(160, 8, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let calm = run_sim(&job, Scheme::Coded, 2, &SimConfig::default());
        let stormy = run_sim(
            &job,
            Scheme::Coded,
            2,
            &SimConfig { straggler_prob: 1.0, straggler_slowdown: 8.0, ..Default::default() },
        );
        assert!(
            stormy.total_ns > calm.total_ns,
            "an 8x slowdown on every worker must stretch virtual time"
        );
    }
}
