//! The deterministic phase engine: one process simulates the `K`-machine
//! cluster phase-by-phase (Map → Encode → Shuffle → Decode → Reduce →
//! state write-back), producing both real results and the paper's metrics.
//!
//! All data *really* flows: Map values are computed, coded messages are
//! XOR-encoded, receivers cancel and reassemble IVs, and the Reduce folds
//! the recovered bits. Wire time comes from the [`Bus`] model; compute
//! time from the [`TimeModel`](super::config::TimeModel) (max over
//! workers for parallel phases). The cluster driver ([`super::cluster`])
//! runs the same job on real threads (or processes) over the wire-format
//! transport layer: its *leader* shares this module's [`PreparedJob`]
//! accounting replay and modeled-time folds (bit-identical metrics),
//! while each *worker* consumes only its own [`PreparedWorker`] shard
//! ([`prepare_worker`]) — membership-sized plan, same canonical orders.
//!
//! ## Architecture (§Perf, unified core)
//!
//! Since PR 5 the engine no longer has its own shuffle data path: one
//! iteration *is* `K` [`WorkerCore`]s — the same per-worker phase
//! machine the cluster drivers run — exchanging serialized frames over
//! an in-memory [`DirectFabric`], plus this module's deterministic
//! accounting replay. Three ideas carry the hot path:
//!
//! 1. **Everything state-independent is precomputed** — the global
//!    [`PreparedJob`] (accounting replay tables, work tallies, the
//!    write-back message list) and, per core, a [`PreparedWorker`]
//!    shard with its routing.
//! 2. **All per-iteration buffers persist**: each core owns its arenas,
//!    the fabric's send logs retain capacity, and both live in the
//!    caller-owned [`EngineScratch`]. After the first iteration warms
//!    the capacities, [`run_iteration_scratch`] performs **zero heap
//!    allocation** on the rust backend (asserted by the `zero_alloc`
//!    integration test on the serial path; under `parallel: true` the
//!    data path is unchanged but rayon's scheduler may allocate
//!    internally).
//! 3. **Phases fan out over cores** (rayon, `parallel` feature +
//!    config flag): each core stages into its own send log and ingests
//!    read-only from all of them, so both phases need no
//!    synchronization, and every floating-point fold and bus merge
//!    replays serially in canonical order — results and metrics are
//!    **bit-identical** across the serial path, the parallel path, any
//!    thread count, and every cluster driver.

use std::time::Instant;

use crate::allocation::Allocation;
use crate::graph::csr::{Csr, Vertex};
use crate::mapreduce::program::VertexProgram;
use crate::mapreduce::sssp::EdgeWeights;
use crate::network::Bus;
use crate::obs::{measured_phase_times, now_ns, Phase, TraceSpan};
#[cfg(feature = "xla")]
use crate::runtime::BlockExecutor;
use crate::shuffle::combined::{
    build_combined_group_plans, build_combined_group_plans_sharded, combined_value,
    plan_uncoded_combined, plan_uncoded_combined_for,
};
#[cfg(feature = "xla")]
use crate::shuffle::decoder::RecoveredIv;
use crate::shuffle::load::{ShuffleLoad, HEADER_BYTES};
use crate::shuffle::plan::{build_group_plans, build_group_plans_sharded, ShufflePlan, WorkerPlan};
use crate::shuffle::segments::seg_bytes;
use crate::shuffle::uncoded::{plan_uncoded, plan_uncoded_for, UncodedTransfer};
use crate::util::par;
use crate::WorkerId;

use super::config::{EngineConfig, Scheme, TimeModel};
use super::exec::{DirectFabric, DirectReceiver, DirectSender, WorkerCore};
use super::metrics::{IterationMetrics, JobReport, PhaseTimes};

/// A distributed graph job: graph + allocation + vertex program.
pub struct Job<'a> {
    pub graph: &'a Csr,
    pub alloc: &'a Allocation,
    pub program: &'a dyn VertexProgram,
}

/// Which artifact family the PJRT backend should run the Reduce with.
#[derive(Clone, Copy, Debug)]
pub enum XlaKind {
    PageRank,
    Sssp(EdgeWeights),
}

/// Reduce-phase compute backend.
#[cfg(feature = "xla")]
pub enum Backend<'e, 'rt> {
    /// Pure-rust fold (default; exact f64).
    Rust,
    /// AOT JAX/Pallas artifacts via PJRT (f32 tiles; see runtime::block).
    Pjrt { exec: &'e mut BlockExecutor<'rt>, kind: XlaKind },
}

/// Reduce-phase compute backend (PJRT variant requires the `xla` feature).
#[cfg(not(feature = "xla"))]
pub enum Backend<'e, 'rt> {
    /// Pure-rust fold (default; exact f64).
    Rust,
    #[doc(hidden)]
    __Uninhabited(
        std::convert::Infallible,
        std::marker::PhantomData<(&'e (), &'rt ())>,
    ),
}

/// Precomputed, state-independent job structures (the paper's
/// pre-processing step): the flat shuffle plan, per-worker work tallies,
/// and every index the steady-state iteration needs.
pub struct PreparedJob {
    pub scheme: Scheme,
    /// Coded multicast plan (empty arena for uncoded schemes).
    pub plan: ShufflePlan,
    /// Uncoded unicast transfers (empty for coded schemes).
    pub transfers: Vec<UncodedTransfer>,
    /// Directed edges Mapped per worker (Map-phase work).
    pub mapped_edges: Vec<usize>,
    /// Directed edges Reduced per worker (Reduce-phase work).
    pub reduce_edges: Vec<usize>,
    /// `reduce_slot[v]` = position of `v` inside its owner's
    /// `reduce_sets` row — the global view of
    /// [`PreparedWorker::reduce_slot`], kept for the sharded-prepare
    /// cross-checks (the data path lives in the worker shards now).
    pub reduce_slot: Vec<u32>,
    /// Per-worker inbound group indices (ascending) — the canonical
    /// decode/fold order the leader's accounting and the ring-sizing
    /// rule share with the worker shards.
    recv_groups: Vec<u32>,
    recv_off: Vec<usize>,
    /// Per-worker transfer indices (uncoded delivery order).
    unc_recv: Vec<u32>,
    unc_recv_off: Vec<usize>,
    /// Per-worker coded send items `(group, sender_idx)`, group-ascending
    /// — the cluster workers' send routing table (flat; worker `k` owns
    /// `send_items[send_off[k]..send_off[k+1]]`).
    send_items: Vec<(u32, u32)>,
    send_off: Vec<usize>,
    /// Per-worker outbound uncoded transfer indices, ascending.
    unc_send: Vec<u32>,
    unc_send_off: Vec<usize>,
    /// Modeled Encode table bytes per worker (state-independent).
    encode_bytes: Vec<usize>,
    /// Modeled Decode bytes per worker (state-independent).
    decode_bytes: Vec<usize>,
    /// State write-back multicasts `(owner, vertex_count, receivers)`,
    /// batch-major then owner-ascending — a deterministic replay list
    /// (the old per-iteration `HashMap` walk had hash-random bus order).
    update_msgs: Vec<(WorkerId, u32, u32)>,
}

impl PreparedJob {
    /// The deterministic state write-back replay list `(owner,
    /// vertex_count, receivers)` (shared with the cluster driver).
    pub fn update_msgs(&self) -> &[(WorkerId, u32, u32)] {
        &self.update_msgs
    }

    /// Coded multicasts worker `k` transmits: `(group, sender_idx)`
    /// pairs, group-ascending — only senders with a non-empty column
    /// count appear (an all-other-rows-empty member sends nothing).
    pub fn send_plan(&self, k: usize) -> &[(u32, u32)] {
        &self.send_items[self.send_off[k]..self.send_off[k + 1]]
    }

    /// Uncoded transfers worker `k` sends (indices into
    /// [`PreparedJob::transfers`], ascending).
    pub fn unc_sends(&self, k: usize) -> &[u32] {
        &self.unc_send[self.unc_send_off[k]..self.unc_send_off[k + 1]]
    }

    /// Multicast groups worker `k` receives from (its row is non-empty),
    /// ascending — the canonical decode/fold order the engine also uses.
    pub fn recv_groups(&self, k: usize) -> &[u32] {
        &self.recv_groups[self.recv_off[k]..self.recv_off[k + 1]]
    }

    /// Uncoded transfers worker `k` receives (indices ascending — the
    /// canonical fold order).
    pub fn unc_recv(&self, k: usize) -> &[u32] {
        &self.unc_recv[self.unc_recv_off[k]..self.unc_recv_off[k + 1]]
    }

    /// Coded messages worker `k` must receive per iteration: one from
    /// each of the other `r` members of every group it has a row in
    /// (whenever `k`'s row is non-empty, every other member's column
    /// count is at least that row's length, so all of them transmit).
    pub fn expect_coded(&self, k: usize) -> usize {
        self.recv_groups(k).len() * (self.plan.members() - 1)
    }

    /// Uncoded unicast batches worker `k` must receive per iteration.
    pub fn expect_unc(&self, k: usize) -> usize {
        self.unc_recv(k).len()
    }

    /// Modeled compute-phase times (max over workers — the paper's
    /// parallel phases): Map, Encode, Decode, Reduce. Shuffle/update are
    /// bus time, not compute, and stay zero here. One implementation
    /// shared by the engine and the cluster leader, so the two replays
    /// cannot drift (the cluster's bit-identical-metrics contract).
    /// Encode/Decode tallies are zero for uncoded schemes (empty plan).
    pub fn modeled_compute_times(&self, time: &TimeModel) -> PhaseTimes {
        Self::compute_times(
            &self.mapped_edges,
            &self.encode_bytes,
            &self.decode_bytes,
            &self.reduce_edges,
            time,
        )
    }

    /// [`PreparedJob::modeled_compute_times`] over caller-supplied work
    /// tallies — shared with the sim fabric, whose per-worker tallies
    /// come from the same tables but get straggler-scaled first.
    pub fn compute_times(
        mapped_edges: &[usize],
        encode_bytes: &[usize],
        decode_bytes: &[usize],
        reduce_edges: &[usize],
        time: &TimeModel,
    ) -> PhaseTimes {
        fn fold_max(per_worker: &[usize], unit_s: f64) -> f64 {
            per_worker.iter().map(|&w| w as f64 * unit_s).fold(0.0, f64::max)
        }
        PhaseTimes {
            map_s: fold_max(mapped_edges, time.map_edge_s),
            encode_s: fold_max(encode_bytes, time.encode_byte_s),
            decode_s: fold_max(decode_bytes, time.decode_byte_s),
            reduce_s: fold_max(reduce_edges, time.reduce_iv_s),
            ..PhaseTimes::default()
        }
    }

    /// Modeled Encode table bytes per worker (state-independent).
    pub fn encode_bytes(&self) -> &[usize] {
        &self.encode_bytes
    }

    /// Modeled Decode bytes per worker (state-independent).
    pub fn decode_bytes(&self) -> &[usize] {
        &self.decode_bytes
    }
}

/// One worker's shard of the prepared job: the local [`WorkerPlan`] (or
/// transfer shard), its own routing tables, and the reducer→slot index —
/// everything [`run_worker`](super::cluster::run_worker) needs, sized by
/// the worker's membership (`≈ (r+1)/K` of the global plan) instead of
/// the whole graph. Built by [`prepare_worker`] without ever
/// materializing the global [`PreparedJob`]; the cluster leader keeps
/// the global one for accounting and ring sizing.
pub struct PreparedWorker {
    pub scheme: Scheme,
    /// The worker this shard belongs to.
    pub me: WorkerId,
    /// Computation load `r`.
    pub r: usize,
    /// Local multicast-group shard (empty for uncoded schemes).
    pub plan: WorkerPlan,
    /// The uncoded transfers this worker sends *or* receives, ascending
    /// by wire id (empty for coded schemes).
    pub transfers: Vec<UncodedTransfer>,
    /// Canonical wire ids (`sender * K + receiver`), 1:1 with
    /// [`PreparedWorker::transfers`], ascending.
    pub transfer_ids: Vec<u64>,
    /// Coded sends: `(local group, sender idx)`, group-ascending.
    send_items: Vec<(u32, u32)>,
    /// Local groups whose own row is non-empty, ascending — the decode
    /// and fold order (identical to the engine's canonical group order).
    recv_locals: Vec<u32>,
    /// Indices into `transfers` this worker sends, ascending.
    unc_send: Vec<u32>,
    /// Indices into `transfers` this worker receives, ascending.
    unc_recv: Vec<u32>,
    /// `reduce_slot[v]` = position of `v` inside this worker's reduce
    /// row (only the worker's own vertices are populated).
    pub reduce_slot: Vec<u32>,
}

impl PreparedWorker {
    /// Coded multicasts this worker transmits: `(local group, sender
    /// idx)` pairs, group-ascending (only senders with a non-zero column
    /// count appear).
    pub fn send_plan(&self) -> &[(u32, u32)] {
        &self.send_items
    }

    /// Local indices of the groups this worker decodes, ascending.
    pub fn recv_groups(&self) -> &[u32] {
        &self.recv_locals
    }

    /// Indices into [`PreparedWorker::transfers`] this worker sends.
    pub fn unc_sends(&self) -> &[u32] {
        &self.unc_send
    }

    /// Indices into [`PreparedWorker::transfers`] this worker receives.
    pub fn unc_recv(&self) -> &[u32] {
        &self.unc_recv
    }

    /// Coded frames expected per iteration: one from each of the other
    /// `r` members of every group this worker has a non-empty row in.
    pub fn expect_coded(&self) -> usize {
        self.recv_locals.len() * self.r
    }

    /// Uncoded unicast batches expected per iteration.
    pub fn expect_unc(&self) -> usize {
        self.unc_recv.len()
    }

    /// Inbound ring bound for this worker's endpoint — the same rule
    /// [`super::cluster::worker_ring_capacity`] applies to the global
    /// tables, so in-process and process-separated runs keep identical
    /// backpressure. Sized at 3× the per-iteration expectation: degraded
    /// mode can leave a failed attempt's frames queued behind a restarted
    /// attempt's full load plus its recovery replacements.
    pub fn ring_capacity(&self) -> usize {
        3 * (self.expect_coded() + self.expect_unc()) + 64
    }
}

/// Build *one worker's* shard of the prepared job — the sharded-path
/// counterpart of [`prepare`]. The worker only materializes the groups
/// (or transfers) it is a party to, in `O(m·(r+1)/K)`; group wire ids
/// are canonical subset ranks and transfer wire ids `sender*K +
/// receiver`, both order-compatible with the global plan, so a cluster
/// of sharded workers stays bit-identical to the engine.
pub fn prepare_worker(job: &Job<'_>, scheme: Scheme, me: WorkerId) -> PreparedWorker {
    let (g, alloc) = (job.graph, job.alloc);
    let r = alloc.r;
    let wk = me as usize;
    let (plan, id_transfers): (WorkerPlan, Vec<(u64, UncodedTransfer)>) = match scheme {
        Scheme::Coded => (build_group_plans_sharded(g, alloc, me), Vec::new()),
        Scheme::Uncoded => {
            (WorkerPlan::empty(me, r + 1, alloc.k), plan_uncoded_for(g, alloc, me))
        }
        Scheme::CodedCombined => (build_combined_group_plans_sharded(g, alloc, me), Vec::new()),
        Scheme::UncodedCombined => (
            WorkerPlan::empty(me, r + 1, alloc.k),
            plan_uncoded_combined_for(g, alloc, me)
                .into_iter()
                .map(|(id, t)| {
                    (
                        id,
                        UncodedTransfer {
                            sender: t.sender,
                            receiver: t.receiver,
                            ivs: t.ivs.into_iter().map(|(i, b)| (i, b as Vertex)).collect(),
                        },
                    )
                })
                .collect(),
        ),
    };

    let mut send_items = Vec::new();
    let mut recv_locals = Vec::new();
    for l in 0..plan.num_groups() {
        let group = plan.group(l);
        for (s_idx, &q) in plan.sender_cols(l).iter().enumerate() {
            if q > 0 && group.servers[s_idx] == me {
                send_items.push((l as u32, s_idx as u32));
            }
        }
        let m_idx = group.member_index(me).expect("sharded plan: worker not a member");
        if group.row_len(m_idx) > 0 {
            recv_locals.push(l as u32);
        }
    }

    let mut transfer_ids = Vec::with_capacity(id_transfers.len());
    let mut transfers = Vec::with_capacity(id_transfers.len());
    for (id, t) in id_transfers {
        transfer_ids.push(id);
        transfers.push(t);
    }
    let mut unc_send = Vec::new();
    let mut unc_recv = Vec::new();
    for (ti, t) in transfers.iter().enumerate() {
        if t.sender == me {
            unc_send.push(ti as u32);
        } else {
            debug_assert_eq!(t.receiver, me, "sharded transfer without its worker");
            unc_recv.push(ti as u32);
        }
    }

    let mut reduce_slot = vec![0u32; alloc.n];
    for (slot, &v) in alloc.reduce_sets[wk].iter().enumerate() {
        reduce_slot[v as usize] = slot as u32;
    }

    PreparedWorker {
        scheme,
        me,
        r,
        plan,
        transfers,
        transfer_ids,
        send_items,
        recv_locals,
        unc_send,
        unc_recv,
        reduce_slot,
    }
}

/// Build the shuffle plan + work tallies + steady-state indices for a job
/// under `scheme`.
pub fn prepare(job: &Job<'_>, scheme: Scheme) -> PreparedJob {
    let (g, alloc) = (job.graph, job.alloc);
    let k = alloc.k;
    let r = alloc.r;
    let mut mapped_edges = vec![0usize; k];
    for (kk, me) in mapped_edges.iter_mut().enumerate() {
        *me = alloc
            .mapped_vertices(kk as WorkerId)
            .map(|j| g.degree(j))
            .sum();
    }
    let mut reduce_edges = vec![0usize; k];
    for (kk, re) in reduce_edges.iter_mut().enumerate() {
        *re = alloc.reduce_sets[kk].iter().map(|&i| g.degree(i)).sum();
    }
    let (plan, transfers) = match scheme {
        Scheme::Coded => (build_group_plans(g, alloc), Vec::new()),
        Scheme::Uncoded => (ShufflePlan::empty(r + 1), plan_uncoded(g, alloc)),
        Scheme::CodedCombined => (build_combined_group_plans(g, alloc), Vec::new()),
        Scheme::UncodedCombined => (
            ShufflePlan::empty(r + 1),
            // combined transfers share the UncodedTransfer shape: the
            // "mapper" slot carries the batch index
            plan_uncoded_combined(g, alloc)
                .into_iter()
                .map(|t| UncodedTransfer {
                    sender: t.sender,
                    receiver: t.receiver,
                    ivs: t.ivs.into_iter().map(|(i, b)| (i, b as Vertex)).collect(),
                })
                .collect(),
        ),
    };

    // reducer -> slot within its owner's row (global cross-check view)
    let mut reduce_slot = vec![0u32; alloc.n];
    for set in &alloc.reduce_sets {
        for (slot, &v) in set.iter().enumerate() {
            reduce_slot[v as usize] = slot as u32;
        }
    }

    // per-worker group routing (coded), send routing, and transfer
    // lists (uncoded), in the exact canonical delivery order — the
    // accounting replay and ring sizing share these tables with the
    // worker shards
    let mut recv_group_lists: Vec<Vec<u32>> = vec![Vec::new(); k];
    let mut send_lists: Vec<Vec<(u32, u32)>> = vec![Vec::new(); k];
    let sb = seg_bytes(r);
    let mut encode_bytes = vec![0usize; k];
    let mut decode_bytes = vec![0usize; k];
    for gi in 0..plan.num_groups() {
        let group = plan.group(gi);
        for (s_idx, &q) in plan.sender_cols(gi).iter().enumerate() {
            if q == 0 {
                continue;
            }
            // encode work: XOR across the sender's table
            let table: usize = (0..group.members())
                .filter(|&i| i != s_idx)
                .map(|i| group.row_len(i) * sb)
                .sum();
            encode_bytes[group.servers[s_idx] as usize] += table;
            send_lists[group.servers[s_idx] as usize].push((gi as u32, s_idx as u32));
        }
        for mi in 0..group.members() {
            let rlen = group.row_len(mi);
            if rlen == 0 {
                continue;
            }
            let worker = group.servers[mi] as usize;
            recv_group_lists[worker].push(gi as u32);
            // decode work: r-1 segment recomputations + 1 XOR per
            // received byte of this member's row
            decode_bytes[worker] += rlen * sb * r;
        }
    }
    let mut recv_groups = Vec::with_capacity(recv_group_lists.iter().map(|l| l.len()).sum());
    let mut recv_off = Vec::with_capacity(k + 1);
    recv_off.push(0);
    for glist in &recv_group_lists {
        recv_groups.extend_from_slice(glist);
        recv_off.push(recv_groups.len());
    }
    let mut send_items = Vec::with_capacity(send_lists.iter().map(|l| l.len()).sum());
    let mut send_off = Vec::with_capacity(k + 1);
    send_off.push(0);
    for list in &send_lists {
        send_items.extend_from_slice(list);
        send_off.push(send_items.len());
    }

    let mut unc_lists: Vec<Vec<u32>> = vec![Vec::new(); k];
    let mut unc_send_lists: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (ti, t) in transfers.iter().enumerate() {
        unc_lists[t.receiver as usize].push(ti as u32);
        unc_send_lists[t.sender as usize].push(ti as u32);
    }
    let mut unc_recv = Vec::with_capacity(transfers.len());
    let mut unc_recv_off = Vec::with_capacity(k + 1);
    unc_recv_off.push(0);
    for list in &unc_lists {
        unc_recv.extend_from_slice(list);
        unc_recv_off.push(unc_recv.len());
    }
    let mut unc_send = Vec::with_capacity(transfers.len());
    let mut unc_send_off = Vec::with_capacity(k + 1);
    unc_send_off.push(0);
    for list in &unc_send_lists {
        unc_send.extend_from_slice(list);
        unc_send_off.push(unc_send.len());
    }

    // state write-back replay list: per (batch, reducer) multicast of the
    // fresh states the reducer owns inside the batch, to the other
    // replica holders (deterministic owner-ascending order)
    let mut update_msgs = Vec::new();
    if r > 1 {
        let mut counts = vec![0u32; k];
        for batch in &alloc.batches {
            for v in batch.vertices() {
                counts[alloc.reduce_owner[v as usize] as usize] += 1;
            }
            for (owner, count) in counts.iter_mut().enumerate() {
                let c = *count;
                if c == 0 {
                    continue;
                }
                *count = 0;
                let others =
                    batch.servers.iter().filter(|&&s| s != owner as WorkerId).count();
                if others == 0 {
                    continue;
                }
                update_msgs.push((owner as WorkerId, c, others as u32));
            }
        }
    }

    PreparedJob {
        scheme,
        plan,
        transfers,
        mapped_edges,
        reduce_edges,
        reduce_slot,
        recv_groups,
        recv_off,
        unc_recv,
        unc_recv_off,
        send_items,
        send_off,
        unc_send,
        unc_send_off,
        encode_bytes,
        decode_bytes,
        update_msgs,
    }
}

/// Reusable per-job scratch: the engine's entire per-iteration working
/// set — `K` [`WorkerCore`]s (each owning its [`PreparedWorker`] shard
/// and arenas) plus the in-memory [`DirectFabric`] they exchange frames
/// over. The cores are built lazily on the first iteration for a given
/// job shape and reused afterwards; capacities grow during the first
/// iteration and stay put, after which [`run_iteration_scratch`]
/// allocates nothing on the rust backend.
#[derive(Default)]
pub struct EngineScratch {
    cores: Vec<WorkerCore>,
    fabric: DirectFabric,
    /// Job fingerprint the cores were built for (see [`ScratchKey`]).
    key: Option<ScratchKey>,
    /// Iterations run since the cores were (re)built — the flight
    /// recorder's iteration tag.
    iters_run: u32,
}

/// Fingerprint of the job a scratch's cores were built for: scheme, the
/// allocation's shape (`K`, `r`, batch count, first reduce-row length —
/// enough to tell this crate's deterministic allocation schemes apart
/// at equal dimensions), the graph's `(n, m)` plus an O(1) structural
/// probe (sampled degrees and adjacency), and the program's identity
/// (name + destination-dependence, which decides the `qbits` fast
/// path). A scratch is still logically *per job*, like a
/// [`PreparedJob`]; the fingerprint exists so accidental reuse on a
/// different job rebuilds the cores instead of corrupting results.
#[derive(Clone, Copy, PartialEq, Eq)]
struct ScratchKey {
    scheme: Scheme,
    k: usize,
    r: usize,
    batches: usize,
    first_reduce_row: usize,
    n: usize,
    m: usize,
    graph_probe: u64,
    program: &'static str,
    dst_dependent: bool,
}

impl ScratchKey {
    fn of(job: &Job<'_>, scheme: Scheme) -> ScratchKey {
        let g = job.graph;
        // cheap per-call structural probe: degree + adjacency samples at
        // 8 fixed positions, so two graphs that merely share (n, m) and
        // allocation shape still rebuild the cores (equal dims with
        // different wiring would otherwise silently reuse stale plans)
        let n = g.n();
        let mut probe = 0xcbf2_9ce4_8422_2325u64; // FNV offset basis
        let samples = if n == 0 { 0 } else { 8usize };
        for s in 0..samples {
            let v = (s * n / 8).min(n - 1) as Vertex;
            let row = g.neighbors(v);
            let sample = ((row.len() as u64) << 32)
                ^ row.first().copied().unwrap_or(0) as u64
                ^ ((row.last().copied().unwrap_or(0) as u64) << 16);
            probe = (probe ^ sample).wrapping_mul(0x1000_0000_01b3);
        }
        ScratchKey {
            scheme,
            k: job.alloc.k,
            r: job.alloc.r,
            batches: job.alloc.batches.len(),
            first_reduce_row: job.alloc.reduce_sets.first().map_or(0, Vec::len),
            n,
            m: g.m(),
            graph_probe: probe,
            program: job.program.name(),
            dst_dependent: job.program.map_depends_on_dst(),
        }
    }
}

impl EngineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build (or reuse) the per-worker cores for `(job, scheme)`.
    /// Reusing a scratch on a different job or scheme rebuilds the
    /// cores (detected via [`ScratchKey`]).
    fn ensure_cores(&mut self, job: &Job<'_>, scheme: Scheme) {
        let key = ScratchKey::of(job, scheme);
        if self.key != Some(key) {
            self.cores = (0..job.alloc.k)
                .map(|kk| WorkerCore::new(job, prepare_worker(job, scheme, kk as WorkerId)))
                .collect();
            self.fabric = DirectFabric::default();
            self.key = Some(key);
            self.iters_run = 0;
        }
    }

    /// Drain every core's flight-recorder spans (oldest first, cores
    /// ascending) into one timeline — the engine's cores are in-process,
    /// so each span's physical worker equals its logical core. Called at
    /// job end (allocates; the per-iteration hot path never drains).
    pub fn take_spans(&mut self) -> Vec<TraceSpan> {
        let mut out = Vec::new();
        for core in &mut self.cores {
            let me = core.me();
            core.drain_spans(me, &mut out);
        }
        out
    }
}

/// Run one full iteration into caller-provided buffers: `next` receives
/// the new state (every vertex is written), `scratch` supplies all
/// working memory (the `K` worker cores and their fabric). Zero
/// steady-state heap allocation on [`Backend::Rust`].
///
/// The data path is the canonical per-worker phase machine
/// ([`WorkerCore`]) over the in-memory [`DirectFabric`]; this function
/// adds only the deterministic accounting replay (bus clock, load
/// tallies, modeled phase times) and the model-vs-staged cross-check —
/// exactly the split the cluster leader uses, so drivers cannot drift.
pub fn run_iteration_scratch(
    job: &Job<'_>,
    prep: &PreparedJob,
    state: &[f64],
    cfg: &EngineConfig,
    backend: &mut Backend<'_, '_>,
    scratch: &mut EngineScratch,
    next: &mut [f64],
) -> IterationMetrics {
    let wall_start = Instant::now();
    let (g, alloc, prog) = (job.graph, job.alloc, job.program);
    let n = g.n();
    assert_eq!(state.len(), n);
    assert_eq!(next.len(), n);
    let k = alloc.k;
    let r = alloc.r;
    let parallel = cfg.parallel;
    let mut times = PhaseTimes::default();
    let mut shuffle_load = ShuffleLoad::default();
    let mut bus = Bus::new(cfg.bus);

    scratch.ensure_cores(job, prep.scheme);
    let iter_tag = scratch.iters_run;
    scratch.iters_run += 1;
    let EngineScratch { cores, fabric, .. } = scratch;
    let cores = cores.as_mut_slice();
    for core in cores.iter_mut() {
        core.set_trace(cfg.trace);
        core.set_trace_iter(iter_tag);
    }

    // ---- Map phase (modeled: parallel across workers) -------------------
    let modeled = prep.modeled_compute_times(&cfg.time);
    times.map_s = modeled.map_s;

    // ---- Shuffle: every core encodes + stages its frames ----------------
    // (rayon fan-out over cores; each core writes only its own send log)
    fabric.begin_iteration(k);
    par::for_each_zip(cores, fabric.logs_mut(), parallel, &|_kk, core, log| {
        core.stage_sends(job, state, &mut DirectSender::new(log));
    });

    // serial accounting replay in canonical (group, sender) / transfer
    // order: bus clock and load tallies are bit-identical however the
    // staging above was scheduled
    match prep.scheme {
        Scheme::Uncoded | Scheme::UncodedCombined => {
            for t in &prep.transfers {
                let bytes = t.ivs.len() * 8 + HEADER_BYTES;
                bus.transmit(t.sender, 1, bytes);
                shuffle_load.add_uncoded(t.ivs.len());
            }
        }
        Scheme::Coded | Scheme::CodedCombined => {
            let plan = &prep.plan;
            let sb = seg_bytes(r);
            for gi in 0..plan.num_groups() {
                let group = plan.group(gi);
                let fanout = group.members() - 1;
                for (s_idx, &q) in plan.sender_cols(gi).iter().enumerate() {
                    if q == 0 {
                        continue;
                    }
                    let q = q as usize;
                    bus.transmit(group.servers[s_idx], fanout, q * sb + HEADER_BYTES);
                    shuffle_load.add_coded(q, r);
                }
            }
            times.encode_s = modeled.encode_s;
            times.decode_s = modeled.decode_s;
        }
    }
    times.shuffle_s = bus.clock();

    // model ≡ staged reality: the frames and serialized bytes the cores
    // actually staged must equal what the replay charged — the same
    // invariant the cluster leader asserts against its transport
    let (staged_frames, staged_bytes) = fabric.tally();
    assert_eq!(
        staged_frames, shuffle_load.messages,
        "cores staged a different frame count than the accounting modeled"
    );
    assert_eq!(
        staged_bytes,
        shuffle_load.wire_bytes_with_headers(),
        "cores staged different wire bytes than the accounting modeled"
    );

    // ---- Ingest → Decode → Reduce ---------------------------------------
    let combined = prep.scheme.is_combined();
    let validate_coded = cfg.validate && prep.scheme.is_coded();
    // bit-level validation oracle: only the engine holds the full state,
    // so only here can every decoded bit be re-derived and asserted (a
    // cluster receiver lacks the source state by design)
    let oracle_fn = |i: Vertex, j: Vertex| -> u64 {
        if combined {
            combined_value(g, alloc, prog, state, i, j as usize).to_bits()
        } else {
            prog.map(i, j, state[j as usize], g).to_bits()
        }
    };
    let oracle: Option<&(dyn Fn(Vertex, Vertex) -> u64 + Sync)> =
        if validate_coded { Some(&oracle_fn) } else { None };
    let mut validated = 0usize;
    match backend {
        Backend::Rust => {
            let logs = fabric.logs();
            par::for_each_mut(cores, parallel, &|kk, core| {
                let mut rx = DirectReceiver::new(logs, kk as WorkerId);
                core.ingest_all(&mut rx);
                core.decode_and_fold(job, state, oracle);
            });
            if validate_coded {
                validated = cores.iter().map(|c| c.last_validated() as usize).sum();
            }
            // state write-back: each vertex is finalized exactly once by
            // its owner core, so the assembly order is immaterial to the
            // values; serial keeps it cheap and obviously deterministic
            for (kk, core) in cores.iter_mut().enumerate() {
                let rows = &alloc.reduce_sets[kk];
                let traced = core.spans_enabled();
                let t0 = if traced { now_ns() } else { 0 };
                for (slot, &i) in rows.iter().enumerate() {
                    next[i as usize] = f64::from_bits(core.next_bits()[slot]);
                }
                if traced {
                    core.note_span(
                        Phase::WriteBack,
                        t0,
                        now_ns() - t0,
                        rows.len() as u64 * 8,
                        rows.len() as u32,
                    );
                }
            }
        }
        #[cfg(feature = "xla")]
        Backend::Pjrt { exec, kind } => {
            assert!(
                !combined,
                "combined schemes are engine/Rust-backend only (the tile \
                 path scatters per-mapper values, not per-batch aggregates)"
            );
            for (kk, core) in cores.iter_mut().enumerate() {
                let mut rx = DirectReceiver::new(fabric.logs(), kk as WorkerId);
                core.ingest_all(&mut rx);
                let received = core.collect_received(oracle);
                reduce_worker_pjrt(
                    g, alloc, prog, state, kk as WorkerId, &received, *kind, exec, next,
                )
                .expect("PJRT reduce");
            }
            if validate_coded {
                validated = cores.iter().map(|c| c.last_validated() as usize).sum();
            }
        }
        #[cfg(not(feature = "xla"))]
        Backend::__Uninhabited(inf, _) => match *inf {},
    }
    times.reduce_s = modeled.reduce_s;

    // ---- State write-back (iterative jobs) --------------------------------
    let mut update_load = ShuffleLoad::default();
    if cfg.account_state_update && r > 1 {
        bus.reset();
        for &(owner, count, others) in &prep.update_msgs {
            let bytes = count as usize * 8 + HEADER_BYTES;
            bus.transmit(owner, others as usize, bytes);
            update_load.add_uncoded(count as usize);
        }
        times.update_s = bus.clock();
    }

    IterationMetrics {
        times,
        wall_s: wall_start.elapsed().as_secs_f64(),
        shuffle: shuffle_load,
        update: update_load,
        validated_ivs: validated,
    }
}

/// PJRT Reduce for one worker: assemble the Map-value vector from local
/// state + received IVs, then run the tiled artifact.
#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
pub fn reduce_worker_pjrt(
    g: &Csr,
    alloc: &Allocation,
    prog: &dyn VertexProgram,
    state: &[f64],
    worker: WorkerId,
    received: &[RecoveredIv],
    kind: XlaKind,
    exec: &mut BlockExecutor<'_>,
    next: &mut [f64],
) -> anyhow::Result<()> {
    let n = g.n();
    let rows = &alloc.reduce_sets[worker as usize];
    // x[j]: the per-mapper tile input. Only local-mapped and received
    // entries are filled — the worker never reads state it doesn't own.
    let mut x = vec![
        match kind {
            XlaKind::PageRank => 0f32,
            XlaKind::Sssp(_) => 3.0e38f32 / 4.0,
        };
        n
    ];
    for j in alloc.mapped_vertices(worker) {
        x[j as usize] = match kind {
            // PageRank tile input is the Map value Π(j)/deg(j); isolated
            // vertices emit nothing (deg 0 would make 0 * inf = NaN in
            // the tile matmul — their adjacency column is all-zero anyway)
            XlaKind::PageRank => {
                if g.degree(j) == 0 {
                    0.0
                } else {
                    prog.map(j, j, state[j as usize], g) as f32
                }
            }
            // SSSP tile input is the raw distance (weights live in the tile)
            XlaKind::Sssp(_) => state[j as usize] as f32,
        };
    }
    for riv in received {
        let v = f64::from_bits(riv.bits);
        x[riv.mapper as usize] = match kind {
            XlaKind::PageRank => v as f32,
            // invert the Map: v = d_j + w(j, i)  =>  d_j = v - w(j, i)
            XlaKind::Sssp(w) => (v - w.weight(riv.mapper, riv.reducer)) as f32,
        };
    }
    let y = match kind {
        XlaKind::PageRank => exec.pagerank_rows(g, rows, &x)?,
        XlaKind::Sssp(w) => exec.sssp_rows(g, rows, &x, w)?,
    };
    for (&i, acc) in rows.iter().zip(y) {
        next[i as usize] = prog.finalize(i, acc, state[i as usize], g);
    }
    Ok(())
}

/// Run a full job for `iters` iterations (double-buffered states, one
/// scratch — steady-state iterations are allocation-free).
pub fn run(
    job: &Job<'_>,
    cfg: &EngineConfig,
    iters: usize,
    backend: &mut Backend<'_, '_>,
) -> JobReport {
    let prep = prepare(job, cfg.scheme);
    let mut state: Vec<f64> = (0..job.graph.n() as Vertex)
        .map(|v| job.program.init(v, job.graph))
        .collect();
    let mut next = vec![0.0f64; job.graph.n()];
    let mut scratch = EngineScratch::new();
    let mut report = JobReport::default();
    for _ in 0..iters {
        let metrics =
            run_iteration_scratch(job, &prep, &state, cfg, backend, &mut scratch, &mut next);
        std::mem::swap(&mut state, &mut next);
        report.iterations.push(metrics);
    }
    report.spans = scratch.take_spans();
    report.measured = measured_phase_times(&report.spans);
    report.final_state = state;
    report
}

/// Convenience: run with the rust backend.
pub fn run_rust(job: &Job<'_>, cfg: &EngineConfig, iters: usize) -> JobReport {
    run(job, cfg, iters, &mut Backend::Rust)
}

/// Run until the program's residual between successive states drops below
/// `tol`, or `max_iters` is reached — the paper's stopping criterion
/// ("the algorithm is stopped when the change ... is less than a
/// pre-defined tolerance"). Returns the report and the iteration count.
pub fn run_until(
    job: &Job<'_>,
    cfg: &EngineConfig,
    tol: f64,
    max_iters: usize,
    backend: &mut Backend<'_, '_>,
) -> (JobReport, usize) {
    let prep = prepare(job, cfg.scheme);
    let mut state: Vec<f64> = (0..job.graph.n() as Vertex)
        .map(|v| job.program.init(v, job.graph))
        .collect();
    let mut next = vec![0.0f64; job.graph.n()];
    let mut scratch = EngineScratch::new();
    let mut report = JobReport::default();
    let mut used = 0;
    for _ in 0..max_iters {
        let metrics =
            run_iteration_scratch(job, &prep, &state, cfg, backend, &mut scratch, &mut next);
        report.iterations.push(metrics);
        used += 1;
        let resid = job.program.residual(&state, &next);
        std::mem::swap(&mut state, &mut next);
        if resid < tol {
            break;
        }
    }
    report.spans = scratch.take_spans();
    report.measured = measured_phase_times(&report.spans);
    report.final_state = state;
    (report, used)
}

/// Uncoded vs coded loads for one (graph, allocation) draw — the Fig 5
/// inner loop. Returns `(uncoded_norm, coded_norm)` normalized loads.
///
/// Plans both schemes; callers holding prebuilt plans (e.g. the Fig 5
/// trial loop) should use [`measure_loads_prepared`] instead.
pub fn measure_loads(g: &Csr, alloc: &Allocation) -> (f64, f64) {
    let plan = build_group_plans(g, alloc);
    let transfers = plan_uncoded(g, alloc);
    measure_loads_prepared(&plan, &transfers, g.n(), alloc.r)
}

/// [`measure_loads`] over prebuilt plans: pure accounting, no planning —
/// the per-sender column counts are already in the [`ShufflePlan`].
pub fn measure_loads_prepared(
    plan: &ShufflePlan,
    transfers: &[UncodedTransfer],
    n: usize,
    r: usize,
) -> (f64, f64) {
    let mut unc = ShuffleLoad::default();
    for t in transfers {
        unc.add_uncoded(t.ivs.len());
    }
    let mut cod = ShuffleLoad::default();
    for gi in 0..plan.num_groups() {
        for &q in plan.sender_cols(gi) {
            if q > 0 {
                cod.add_coded(q as usize, r);
            }
        }
    }
    (unc.normalized(n), cod.normalized(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::er::er;
    use crate::mapreduce::program::run_single_machine;
    use crate::mapreduce::{PageRank, Sssp};
    use crate::util::rng::DetRng;

    fn cfg(scheme: Scheme) -> EngineConfig {
        EngineConfig { scheme, validate: true, ..Default::default() }
    }

    #[test]
    fn coded_pagerank_matches_single_machine() {
        let g = er(150, 0.1, &mut DetRng::seed(41));
        let alloc = Allocation::er_scheme(150, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 5);
        let want = run_single_machine(&prog, &g, 5);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        assert!(report.iterations[0].validated_ivs > 0);
    }

    #[test]
    fn uncoded_pagerank_matches_single_machine() {
        let g = er(150, 0.1, &mut DetRng::seed(42));
        let alloc = Allocation::er_scheme(150, 5, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Uncoded), 4);
        let want = run_single_machine(&prog, &g, 4);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn coded_sssp_matches_single_machine() {
        let g = er(120, 0.08, &mut DetRng::seed(43));
        let alloc = Allocation::er_scheme(120, 4, 2);
        let prog = Sssp::hashed(0);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 6);
        let want = run_single_machine(&prog, &g, 6);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn coded_r_equals_one_matches_single_machine() {
        // degenerate coding (2-member groups, whole-IV "segments")
        let g = er(100, 0.1, &mut DetRng::seed(51));
        let alloc = Allocation::er_scheme(100, 4, 1);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 4);
        let want = run_single_machine(&prog, &g, 4);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!(report.iterations[0].validated_ivs > 0);
    }

    #[test]
    fn serial_and_parallel_paths_bit_identical() {
        let g = er(200, 0.12, &mut DetRng::seed(52));
        let alloc = Allocation::er_scheme(200, 5, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        for scheme in [Scheme::Coded, Scheme::Uncoded, Scheme::CodedCombined] {
            let serial = run_rust(
                &job,
                &EngineConfig { scheme, parallel: false, ..Default::default() },
                4,
            );
            let par = run_rust(
                &job,
                &EngineConfig { scheme, parallel: true, ..Default::default() },
                4,
            );
            for (a, b) in serial.final_state.iter().zip(&par.final_state) {
                assert_eq!(a.to_bits(), b.to_bits(), "{scheme}: {a} vs {b}");
            }
            for (ms, mp) in serial.iterations.iter().zip(&par.iterations) {
                assert_eq!(ms.shuffle.paper_bits, mp.shuffle.paper_bits);
                assert_eq!(ms.shuffle.wire_payload_bytes, mp.shuffle.wire_payload_bytes);
                assert_eq!(ms.shuffle.messages, mp.shuffle.messages);
                assert_eq!(ms.times.shuffle_s, mp.times.shuffle_s);
                assert_eq!(ms.times.update_s, mp.times.update_s);
            }
        }
    }

    #[test]
    fn scratch_reuse_is_stable() {
        // the same scratch across many iterations must keep producing the
        // same states as fresh buffers every time
        let g = er(120, 0.1, &mut DetRng::seed(53));
        let alloc = Allocation::er_scheme(120, 4, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let config = cfg(Scheme::Coded);
        let prep = prepare(&job, Scheme::Coded);
        let mut state: Vec<f64> = (0..120u32).map(|v| prog.init(v, &g)).collect();
        let mut next = vec![0.0f64; 120];
        let mut scratch = EngineScratch::new();
        for _ in 0..5 {
            // fresh-core reference for this exact state
            let mut fresh = EngineScratch::new();
            let mut want = vec![0.0f64; 120];
            run_iteration_scratch(
                &job, &prep, &state, &config, &mut Backend::Rust, &mut fresh, &mut want,
            );
            run_iteration_scratch(
                &job, &prep, &state, &config, &mut Backend::Rust, &mut scratch, &mut next,
            );
            for (a, b) in next.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            std::mem::swap(&mut state, &mut next);
        }
    }

    #[test]
    fn prepared_routing_tables_are_consistent() {
        // the cluster's routing tables (one precomputed source of truth
        // in PreparedJob) must agree with a direct recount from the plan
        let g = er(140, 0.12, &mut DetRng::seed(55));
        for (scheme, r) in [
            (Scheme::Coded, 2),
            (Scheme::Coded, 1),
            (Scheme::Uncoded, 3),
            (Scheme::CodedCombined, 2),
        ] {
            let alloc = Allocation::er_scheme(140, 5, r);
            let prog = PageRank::default();
            let job = Job { graph: &g, alloc: &alloc, program: &prog };
            let prep = prepare(&job, scheme);
            let plan = &prep.plan;
            let mut sends = 0usize;
            for kk in 0..5 {
                for &(gi, si) in prep.send_plan(kk) {
                    assert!(plan.sender_cols(gi as usize)[si as usize] > 0);
                    assert_eq!(plan.group(gi as usize).servers[si as usize] as usize, kk);
                    sends += 1;
                }
                assert!(prep.send_plan(kk).windows(2).all(|w| w[0].0 <= w[1].0));
                for &gi in prep.recv_groups(kk) {
                    let group = plan.group(gi as usize);
                    let mi = group.member_index(kk as WorkerId).unwrap();
                    assert!(group.row_len(mi) > 0, "recv group with empty row");
                }
                assert!(prep.recv_groups(kk).windows(2).all(|w| w[0] < w[1]));
                for &ti in prep.unc_sends(kk) {
                    assert_eq!(prep.transfers[ti as usize].sender as usize, kk);
                }
                for &ti in prep.unc_recv(kk) {
                    assert_eq!(prep.transfers[ti as usize].receiver as usize, kk);
                }
                assert_eq!(prep.expect_unc(kk), prep.unc_recv(kk).len());
                // everyone a row expects from transmits: r messages/group
                assert_eq!(prep.expect_coded(kk), prep.recv_groups(kk).len() * r);
            }
            // every transmitting (group, sender) appears exactly once
            let want_sends: usize = (0..plan.num_groups())
                .map(|gi| plan.sender_cols(gi).iter().filter(|&&q| q > 0).count())
                .sum();
            assert_eq!(sends, want_sends, "{scheme} r={r}");
            let total_unc: usize = (0..5).map(|kk| prep.unc_sends(kk).len()).sum();
            assert_eq!(total_unc, prep.transfers.len());
        }
    }

    #[test]
    fn prepare_worker_matches_global_routing() {
        // the sharded prepare must reproduce exactly the per-worker slice
        // of the global routing tables: send/recv groups (via subset-rank
        // wire ids), expected frame counts, transfers, and reduce slots
        use crate::combinatorics::subset_rank;
        let g = er(150, 0.12, &mut DetRng::seed(56));
        for (scheme, r) in [
            (Scheme::Coded, 2),
            (Scheme::Coded, 1),
            (Scheme::Uncoded, 3),
            (Scheme::CodedCombined, 2),
            (Scheme::UncodedCombined, 2),
        ] {
            let k = 5usize;
            let alloc = Allocation::er_scheme(150, k, r);
            let prog = PageRank::default();
            let job = Job { graph: &g, alloc: &alloc, program: &prog };
            let prep = prepare(&job, scheme);
            for me in 0..k as WorkerId {
                let pw = prepare_worker(&job, scheme, me);
                assert_eq!(pw.me, me);
                assert_eq!(pw.r, r);
                // coded routing: same (group, sender) sequence via wire ids
                let want_sends: Vec<(u64, u32)> = prep
                    .send_plan(me as usize)
                    .iter()
                    .map(|&(gi, si)| {
                        (subset_rank(k, prep.plan.group(gi as usize).servers), si)
                    })
                    .collect();
                let got_sends: Vec<(u64, u32)> = pw
                    .send_plan()
                    .iter()
                    .map(|&(l, si)| (pw.plan.wire_id(l as usize), si))
                    .collect();
                assert_eq!(got_sends, want_sends, "{scheme} me={me}");
                let want_recv: Vec<u64> = prep
                    .recv_groups(me as usize)
                    .iter()
                    .map(|&gi| subset_rank(k, prep.plan.group(gi as usize).servers))
                    .collect();
                let got_recv: Vec<u64> = pw
                    .recv_groups()
                    .iter()
                    .map(|&l| pw.plan.wire_id(l as usize))
                    .collect();
                assert_eq!(got_recv, want_recv, "{scheme} me={me}");
                assert_eq!(pw.expect_coded(), prep.expect_coded(me as usize));
                assert_eq!(pw.expect_unc(), prep.expect_unc(me as usize));
                // uncoded routing: the same transfers, in the same order
                let want_send_ti: Vec<&UncodedTransfer> = prep
                    .unc_sends(me as usize)
                    .iter()
                    .map(|&ti| &prep.transfers[ti as usize])
                    .collect();
                let got_send_ti: Vec<&UncodedTransfer> =
                    pw.unc_sends().iter().map(|&ti| &pw.transfers[ti as usize]).collect();
                assert_eq!(got_send_ti.len(), want_send_ti.len());
                for (a, b) in got_send_ti.iter().zip(&want_send_ti) {
                    assert_eq!((a.sender, a.receiver), (b.sender, b.receiver));
                    assert_eq!(a.ivs, b.ivs, "{scheme} me={me}");
                }
                let want_recv_ti: Vec<&UncodedTransfer> = prep
                    .unc_recv(me as usize)
                    .iter()
                    .map(|&ti| &prep.transfers[ti as usize])
                    .collect();
                let got_recv_ti: Vec<&UncodedTransfer> =
                    pw.unc_recv().iter().map(|&ti| &pw.transfers[ti as usize]).collect();
                assert_eq!(got_recv_ti.len(), want_recv_ti.len());
                for (a, b) in got_recv_ti.iter().zip(&want_recv_ti) {
                    assert_eq!((a.sender, a.receiver), (b.sender, b.receiver));
                    assert_eq!(a.ivs, b.ivs, "{scheme} me={me}");
                }
                // reduce slots agree on every vertex this worker owns
                for &v in &alloc.reduce_sets[me as usize] {
                    assert_eq!(pw.reduce_slot[v as usize], prep.reduce_slot[v as usize]);
                }
                let leader_view =
                    super::super::cluster::worker_ring_capacity(&prep, me as usize);
                assert_eq!(pw.ring_capacity(), leader_view, "{scheme} me={me}");
            }
        }
    }

    #[test]
    fn coded_load_beats_uncoded() {
        let g = er(200, 0.1, &mut DetRng::seed(44));
        for r in 2..5 {
            let alloc = Allocation::er_scheme(200, 5, r);
            let (unc, cod) = measure_loads(&g, &alloc);
            assert!(cod < unc, "r={r}: coded {cod} >= uncoded {unc}");
            // gain should be near r
            let gain = unc / cod;
            assert!(gain > 0.7 * r as f64, "r={r}: gain {gain}");
        }
    }

    #[test]
    fn measure_loads_prepared_matches_wrapper() {
        let g = er(180, 0.15, &mut DetRng::seed(54));
        for r in 1..5 {
            let alloc = Allocation::er_scheme(180, 5, r);
            let plan = build_group_plans(&g, &alloc);
            let transfers = plan_uncoded(&g, &alloc);
            let direct = measure_loads(&g, &alloc);
            let prepared = measure_loads_prepared(&plan, &transfers, g.n(), alloc.r);
            assert_eq!(direct, prepared, "r={r}");
        }
    }

    #[test]
    fn r_equals_one_single_naive_has_no_update_cost() {
        let g = er(100, 0.1, &mut DetRng::seed(45));
        let alloc = Allocation::single(100, 5);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Uncoded), 2);
        assert_eq!(report.iterations[0].times.update_s, 0.0);
        assert_eq!(report.iterations[0].update.messages, 0);
    }

    #[test]
    fn combined_schemes_match_single_machine() {
        let g = er(140, 0.2, &mut DetRng::seed(47));
        let alloc = Allocation::er_scheme(140, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let want = run_single_machine(&prog, &g, 4);
        for scheme in [Scheme::CodedCombined, Scheme::UncodedCombined] {
            let report = run_rust(&job, &cfg(scheme), 4);
            for (a, b) in report.final_state.iter().zip(&want) {
                assert!((a - b).abs() < 1e-13, "{scheme}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn combined_coded_load_below_plain_coded_on_dense_graph() {
        let g = er(200, 0.4, &mut DetRng::seed(48));
        let alloc = Allocation::er_scheme(200, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let plain = run_rust(&job, &cfg(Scheme::Coded), 1).iterations[0]
            .shuffle
            .normalized(200);
        let comb = run_rust(&job, &cfg(Scheme::CodedCombined), 1).iterations[0]
            .shuffle
            .normalized(200);
        assert!(comb < plain / 3.0, "combined {comb} vs plain {plain}");
    }

    #[test]
    fn combined_sssp_min_aggregates_correctly() {
        // min is a valid combiner monoid too
        let g = er(100, 0.15, &mut DetRng::seed(49));
        let alloc = Allocation::er_scheme(100, 4, 2);
        let prog = Sssp::hashed(3);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let want = run_single_machine(&prog, &g, 6);
        let report = run_rust(&job, &cfg(Scheme::CodedCombined), 6);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn run_until_stops_at_tolerance() {
        let g = er(150, 0.1, &mut DetRng::seed(50));
        let alloc = Allocation::er_scheme(150, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let (report, used) = run_until(
            &job,
            &cfg(Scheme::Coded),
            1e-10,
            200,
            &mut Backend::Rust,
        );
        assert!(used < 200, "should converge well before the cap");
        assert!(used > 3, "should take a few iterations");
        assert_eq!(report.iterations.len(), used);
        // converged: one more iteration barely moves
        let more = run_single_machine(&prog, &g, used + 1);
        let resid: f64 = report
            .final_state
            .iter()
            .zip(&more)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(resid < 1e-8, "resid {resid}");
    }

    #[test]
    fn phase_times_populated() {
        let g = er(200, 0.15, &mut DetRng::seed(46));
        let alloc = Allocation::er_scheme(200, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 1);
        let t = &report.iterations[0].times;
        assert!(t.map_s > 0.0 && t.shuffle_s > 0.0 && t.reduce_s > 0.0);
        assert!(t.encode_s > 0.0 && t.decode_s > 0.0);
        assert!(t.update_s > 0.0);
        assert!(report.iterations[0].wall_s > 0.0);
    }
}
