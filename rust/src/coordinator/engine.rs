//! The deterministic phase engine: one process simulates the `K`-machine
//! cluster phase-by-phase (Map → Encode → Shuffle → Decode → Reduce →
//! state write-back), producing both real results and the paper's metrics.
//!
//! All data *really* flows: Map values are computed, coded messages are
//! XOR-encoded, receivers cancel and reassemble IVs, and the Reduce folds
//! the recovered bits. Wire time comes from the [`Bus`] model; compute
//! time from the [`TimeModel`] (max over workers for parallel phases).
//! The threaded driver ([`super::cluster`]) runs the same phase functions
//! on real threads with real channels.

use crate::allocation::Allocation;
use crate::graph::csr::{Csr, Vertex};
use crate::mapreduce::program::VertexProgram;
use crate::mapreduce::sssp::EdgeWeights;
use crate::network::Bus;
use crate::runtime::BlockExecutor;
use crate::shuffle::coded::{encode_sender, row_values};
use crate::shuffle::combined::{
    build_combined_group_plans, combined_value, plan_uncoded_combined,
};
use crate::shuffle::decoder::{recover_group_shared, RecoveredIv};
use crate::shuffle::load::{ShuffleLoad, HEADER_BYTES};
use crate::shuffle::plan::{build_group_plans, GroupPlan};
use crate::shuffle::segments::seg_bytes;
use crate::shuffle::uncoded::{plan_uncoded, UncodedTransfer};

use super::config::{EngineConfig, Scheme};
use super::metrics::{IterationMetrics, JobReport, PhaseTimes};

/// A distributed graph job: graph + allocation + vertex program.
pub struct Job<'a> {
    pub graph: &'a Csr,
    pub alloc: &'a Allocation,
    pub program: &'a dyn VertexProgram,
}

/// Which artifact family the PJRT backend should run the Reduce with.
#[derive(Clone, Copy, Debug)]
pub enum XlaKind {
    PageRank,
    Sssp(EdgeWeights),
}

/// Reduce-phase compute backend.
pub enum Backend<'e, 'rt> {
    /// Pure-rust fold (default; exact f64).
    Rust,
    /// AOT JAX/Pallas artifacts via PJRT (f32 tiles; see runtime::block).
    Pjrt { exec: &'e mut BlockExecutor<'rt>, kind: XlaKind },
}

/// Precomputed, state-independent job structures (the paper's
/// pre-processing step): shuffle plans and per-worker work tallies.
pub struct PreparedJob {
    pub scheme: Scheme,
    pub groups: Vec<GroupPlan>,
    pub transfers: Vec<UncodedTransfer>,
    /// Directed edges Mapped per worker (Map-phase work).
    pub mapped_edges: Vec<usize>,
    /// Directed edges Reduced per worker (Reduce-phase work).
    pub reduce_edges: Vec<usize>,
}

/// Build the shuffle plan + work tallies for a job under `scheme`.
pub fn prepare(job: &Job<'_>, scheme: Scheme) -> PreparedJob {
    let (g, alloc) = (job.graph, job.alloc);
    let k = alloc.k;
    let mut mapped_edges = vec![0usize; k];
    for (kk, me) in mapped_edges.iter_mut().enumerate() {
        *me = alloc
            .mapped_vertices(kk as u8)
            .map(|j| g.degree(j))
            .sum();
    }
    let mut reduce_edges = vec![0usize; k];
    for (kk, re) in reduce_edges.iter_mut().enumerate() {
        *re = alloc.reduce_sets[kk].iter().map(|&i| g.degree(i)).sum();
    }
    let (groups, transfers) = match scheme {
        Scheme::Coded => (build_group_plans(g, alloc), Vec::new()),
        Scheme::Uncoded => (Vec::new(), plan_uncoded(g, alloc)),
        Scheme::CodedCombined => (build_combined_group_plans(g, alloc), Vec::new()),
        Scheme::UncodedCombined => (
            Vec::new(),
            // combined transfers share the UncodedTransfer shape: the
            // "mapper" slot carries the batch index
            plan_uncoded_combined(g, alloc)
                .into_iter()
                .map(|t| UncodedTransfer {
                    sender: t.sender,
                    receiver: t.receiver,
                    ivs: t.ivs.into_iter().map(|(i, b)| (i, b as Vertex)).collect(),
                })
                .collect(),
        ),
    };
    PreparedJob { scheme, groups, transfers, mapped_edges, reduce_edges }
}

/// Run one full iteration; returns the next state and the metrics.
pub fn run_iteration(
    job: &Job<'_>,
    prep: &PreparedJob,
    state: &[f64],
    cfg: &EngineConfig,
    backend: &mut Backend<'_, '_>,
) -> (Vec<f64>, IterationMetrics) {
    let wall_start = std::time::Instant::now();
    let (g, alloc, prog) = (job.graph, job.alloc, job.program);
    let n = g.n();
    assert_eq!(state.len(), n);
    let k = alloc.k;
    let r = alloc.r;
    let mut times = PhaseTimes::default();
    let mut shuffle_load = ShuffleLoad::default();
    let mut bus = Bus::new(cfg.bus);
    let mut validated = 0usize;

    // The Map closure both schemes and the decoder share: IV bits for edge
    // (dst i <- src j). Pure function of (i, j, state[j]). When the program
    // declares dst-independence (PageRank), evaluate each Mapper once up
    // front — O(n) instead of O(r·m) dyn-dispatched calls (§Perf).
    // combined schemes: the "mapper" slot of an IV key is a batch index
    // and the value is the per-(Reducer, batch) pre-aggregate
    let combined = prep.scheme.is_combined();
    let src_only = !combined && !prog.map_depends_on_dst();
    let qbits: Vec<u64> = if src_only {
        (0..n as Vertex)
            .map(|j| {
                if g.degree(j) == 0 {
                    0
                } else {
                    prog.map(j, j, state[j as usize], g).to_bits()
                }
            })
            .collect()
    } else {
        Vec::new()
    };
    let value = |i: Vertex, j: Vertex| {
        if combined {
            combined_value(g, alloc, prog, state, i, j as usize).to_bits()
        } else if src_only {
            qbits[j as usize]
        } else {
            prog.map(i, j, state[j as usize], g).to_bits()
        }
    };

    // ---- Map phase (modeled: parallel across workers) -------------------
    times.map_s = prep
        .mapped_edges
        .iter()
        .map(|&e| e as f64 * cfg.time.map_edge_s)
        .fold(0.0, f64::max);

    // ---- Shuffle (Encode → bus → Decode) --------------------------------
    let mut received: Vec<Vec<RecoveredIv>> = vec![Vec::new(); k];
    match prep.scheme {
        Scheme::Uncoded | Scheme::UncodedCombined => {
            for t in &prep.transfers {
                let bytes = t.ivs.len() * 8 + HEADER_BYTES;
                bus.transmit(t.sender, 1, bytes);
                shuffle_load.add_uncoded(t.ivs.len());
                let dst = &mut received[t.receiver as usize];
                dst.reserve(t.ivs.len());
                for &(i, j) in &t.ivs {
                    dst.push(RecoveredIv { reducer: i, mapper: j, bits: value(i, j) });
                }
            }
            times.shuffle_s = bus.clock();
        }
        Scheme::Coded | Scheme::CodedCombined => {
            let sb = seg_bytes(r);
            let mut encode_bytes = vec![0usize; k];
            let mut decode_bytes = vec![0usize; k];
            for plan in &prep.groups {
                // row values evaluated once and shared by the encoder and
                // every receiver's decoder (§Perf: saves ~r re-derivations)
                let vals = row_values(plan, &value);
                let msgs: Vec<_> = (0..plan.servers.len())
                    .map(|s_idx| encode_sender(plan, s_idx, &vals, r))
                    .collect();
                for (s_idx, msg) in msgs.iter().enumerate() {
                    if msg.columns.is_empty() {
                        continue;
                    }
                    let sender = plan.servers[s_idx];
                    let bytes = msg.payload_bytes(r) + HEADER_BYTES;
                    bus.transmit(sender, plan.servers.len() - 1, bytes);
                    shuffle_load.add_coded(msg.columns.len(), r);
                    // encode work: XOR across the sender's table
                    let table: usize = plan
                        .rows
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != s_idx)
                        .map(|(_, row)| row.len() * sb)
                        .sum();
                    encode_bytes[sender as usize] += table;
                }
                for (m_idx, &member) in plan.servers.iter().enumerate() {
                    if plan.rows[m_idx].is_empty() {
                        continue;
                    }
                    let ivs = recover_group_shared(plan, m_idx, &msgs, &vals, r);
                    // decode work: r-1 segment recomputations + 1 XOR per
                    // received byte of this member's row
                    decode_bytes[member as usize] += plan.rows[m_idx].len() * sb * r;
                    if cfg.validate {
                        for riv in &ivs {
                            assert_eq!(
                                riv.bits,
                                value(riv.reducer, riv.mapper),
                                "coded decode mismatch at ({}, {})",
                                riv.reducer,
                                riv.mapper
                            );
                            validated += 1;
                        }
                    }
                    received[member as usize].extend(ivs);
                }
            }
            times.shuffle_s = bus.clock();
            times.encode_s = encode_bytes
                .iter()
                .map(|&b| b as f64 * cfg.time.encode_byte_s)
                .fold(0.0, f64::max);
            times.decode_s = decode_bytes
                .iter()
                .map(|&b| b as f64 * cfg.time.decode_byte_s)
                .fold(0.0, f64::max);
        }
    }

    // ---- Reduce phase ----------------------------------------------------
    let mut next = vec![0.0f64; n];
    match backend {
        Backend::Rust => {
            for kk in 0..k {
                reduce_worker_rust(g, alloc, prog, state, kk as u8, &received[kk], &mut next);
            }
        }
        Backend::Pjrt { exec, kind } => {
            assert!(
                !combined,
                "combined schemes are engine/Rust-backend only (the tile \
                 path scatters per-mapper values, not per-batch aggregates)"
            );
            for kk in 0..k {
                reduce_worker_pjrt(
                    g, alloc, prog, state, kk as u8, &received[kk], *kind, exec, &mut next,
                )
                .expect("PJRT reduce");
            }
        }
    }
    times.reduce_s = prep
        .reduce_edges
        .iter()
        .map(|&e| e as f64 * cfg.time.reduce_iv_s)
        .fold(0.0, f64::max);

    // ---- State write-back (iterative jobs) --------------------------------
    let mut update_load = ShuffleLoad::default();
    if cfg.account_state_update && r > 1 {
        bus.reset();
        for batch in &alloc.batches {
            // per (batch, reducer) multicast: reducer sends fresh states of
            // its vertices in this batch to the other replica holders
            let mut per_reducer = std::collections::HashMap::<u8, usize>::new();
            for v in batch.vertices() {
                *per_reducer.entry(alloc.reduce_owner[v as usize]).or_default() += 1;
            }
            for (&owner, &count) in &per_reducer {
                let others = batch.servers.iter().filter(|&&s| s != owner).count();
                if others == 0 {
                    continue;
                }
                let bytes = count * 8 + HEADER_BYTES;
                bus.transmit(owner, others, bytes);
                update_load.add_uncoded(count);
            }
        }
        times.update_s = bus.clock();
    }

    let metrics = IterationMetrics {
        times,
        wall_s: wall_start.elapsed().as_secs_f64(),
        shuffle: shuffle_load,
        update: update_load,
        validated_ivs: validated,
    };
    (next, metrics)
}

/// Pure-rust Reduce for one worker: fold local + received IVs.
pub fn reduce_worker_rust(
    g: &Csr,
    alloc: &Allocation,
    prog: &dyn VertexProgram,
    state: &[f64],
    worker: u8,
    received: &[RecoveredIv],
    next: &mut [f64],
) {
    let rows = &alloc.reduce_sets[worker as usize];
    let mut accs: Vec<f64> = Vec::with_capacity(rows.len());
    for &i in rows {
        let mut acc = prog.identity();
        for &j in g.neighbors(i) {
            if alloc.maps(worker, j) {
                acc = prog.combine(acc, prog.map(i, j, state[j as usize], g));
            }
        }
        accs.push(acc);
    }
    for riv in received {
        let pos = rows
            .binary_search(&riv.reducer)
            .expect("received IV for a vertex this worker does not reduce");
        accs[pos] = prog.combine(accs[pos], f64::from_bits(riv.bits));
    }
    for (&i, acc) in rows.iter().zip(accs) {
        next[i as usize] = prog.finalize(i, acc, state[i as usize], g);
    }
}

/// PJRT Reduce for one worker: assemble the Map-value vector from local
/// state + received IVs, then run the tiled artifact.
#[allow(clippy::too_many_arguments)]
pub fn reduce_worker_pjrt(
    g: &Csr,
    alloc: &Allocation,
    prog: &dyn VertexProgram,
    state: &[f64],
    worker: u8,
    received: &[RecoveredIv],
    kind: XlaKind,
    exec: &mut BlockExecutor<'_>,
    next: &mut [f64],
) -> anyhow::Result<()> {
    let n = g.n();
    let rows = &alloc.reduce_sets[worker as usize];
    // x[j]: the per-mapper tile input. Only local-mapped and received
    // entries are filled — the worker never reads state it doesn't own.
    let mut x = vec![
        match kind {
            XlaKind::PageRank => 0f32,
            XlaKind::Sssp(_) => 3.0e38f32 / 4.0,
        };
        n
    ];
    for j in alloc.mapped_vertices(worker) {
        x[j as usize] = match kind {
            // PageRank tile input is the Map value Π(j)/deg(j); isolated
            // vertices emit nothing (deg 0 would make 0 * inf = NaN in
            // the tile matmul — their adjacency column is all-zero anyway)
            XlaKind::PageRank => {
                if g.degree(j) == 0 {
                    0.0
                } else {
                    prog.map(j, j, state[j as usize], g) as f32
                }
            }
            // SSSP tile input is the raw distance (weights live in the tile)
            XlaKind::Sssp(_) => state[j as usize] as f32,
        };
    }
    for riv in received {
        let v = f64::from_bits(riv.bits);
        x[riv.mapper as usize] = match kind {
            XlaKind::PageRank => v as f32,
            // invert the Map: v = d_j + w(j, i)  =>  d_j = v - w(j, i)
            XlaKind::Sssp(w) => (v - w.weight(riv.mapper, riv.reducer)) as f32,
        };
    }
    let y = match kind {
        XlaKind::PageRank => exec.pagerank_rows(g, rows, &x)?,
        XlaKind::Sssp(w) => exec.sssp_rows(g, rows, &x, w)?,
    };
    for (&i, acc) in rows.iter().zip(y) {
        next[i as usize] = prog.finalize(i, acc, state[i as usize], g);
    }
    Ok(())
}

/// Run a full job for `iters` iterations.
pub fn run(
    job: &Job<'_>,
    cfg: &EngineConfig,
    iters: usize,
    backend: &mut Backend<'_, '_>,
) -> JobReport {
    let prep = prepare(job, cfg.scheme);
    let mut state: Vec<f64> = (0..job.graph.n() as Vertex)
        .map(|v| job.program.init(v, job.graph))
        .collect();
    let mut report = JobReport::default();
    for _ in 0..iters {
        let (next, metrics) = run_iteration(job, &prep, &state, cfg, backend);
        state = next;
        report.iterations.push(metrics);
    }
    report.final_state = state;
    report
}

/// Convenience: run with the rust backend.
pub fn run_rust(job: &Job<'_>, cfg: &EngineConfig, iters: usize) -> JobReport {
    run(job, cfg, iters, &mut Backend::Rust)
}

/// Run until the program's residual between successive states drops below
/// `tol`, or `max_iters` is reached — the paper's stopping criterion
/// ("the algorithm is stopped when the change ... is less than a
/// pre-defined tolerance"). Returns the report and the iteration count.
pub fn run_until(
    job: &Job<'_>,
    cfg: &EngineConfig,
    tol: f64,
    max_iters: usize,
    backend: &mut Backend<'_, '_>,
) -> (JobReport, usize) {
    let prep = prepare(job, cfg.scheme);
    let mut state: Vec<f64> = (0..job.graph.n() as Vertex)
        .map(|v| job.program.init(v, job.graph))
        .collect();
    let mut report = JobReport::default();
    let mut used = 0;
    for _ in 0..max_iters {
        let (next, metrics) = run_iteration(job, &prep, &state, cfg, backend);
        report.iterations.push(metrics);
        used += 1;
        let resid = job.program.residual(&state, &next);
        state = next;
        if resid < tol {
            break;
        }
    }
    report.final_state = state;
    (report, used)
}

/// Uncoded vs coded loads for one (graph, allocation) draw — the Fig 5
/// inner loop. Returns `(uncoded_norm, coded_norm)` normalized loads.
pub fn measure_loads(g: &Csr, alloc: &Allocation) -> (f64, f64) {
    let n = g.n();
    let r = alloc.r;
    let mut unc = ShuffleLoad::default();
    for t in plan_uncoded(g, alloc) {
        unc.add_uncoded(t.ivs.len());
    }
    let mut cod = ShuffleLoad::default();
    for plan in build_group_plans(g, alloc) {
        for (s_idx, _) in plan.servers.iter().enumerate() {
            let q = plan
                .rows
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != s_idx)
                .map(|(_, row)| row.len())
                .max()
                .unwrap_or(0);
            if q > 0 {
                cod.add_coded(q, r);
            }
        }
    }
    (unc.normalized(n), cod.normalized(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::er::er;
    use crate::mapreduce::program::run_single_machine;
    use crate::mapreduce::{PageRank, Sssp};
    use crate::util::rng::DetRng;

    fn cfg(scheme: Scheme) -> EngineConfig {
        EngineConfig { scheme, validate: true, ..Default::default() }
    }

    #[test]
    fn coded_pagerank_matches_single_machine() {
        let g = er(150, 0.1, &mut DetRng::seed(41));
        let alloc = Allocation::er_scheme(150, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 5);
        let want = run_single_machine(&prog, &g, 5);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        assert!(report.iterations[0].validated_ivs > 0);
    }

    #[test]
    fn uncoded_pagerank_matches_single_machine() {
        let g = er(150, 0.1, &mut DetRng::seed(42));
        let alloc = Allocation::er_scheme(150, 5, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Uncoded), 4);
        let want = run_single_machine(&prog, &g, 4);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn coded_sssp_matches_single_machine() {
        let g = er(120, 0.08, &mut DetRng::seed(43));
        let alloc = Allocation::er_scheme(120, 4, 2);
        let prog = Sssp::hashed(0);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 6);
        let want = run_single_machine(&prog, &g, 6);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn coded_load_beats_uncoded() {
        let g = er(200, 0.1, &mut DetRng::seed(44));
        for r in 2..5 {
            let alloc = Allocation::er_scheme(200, 5, r);
            let (unc, cod) = measure_loads(&g, &alloc);
            assert!(cod < unc, "r={r}: coded {cod} >= uncoded {unc}");
            // gain should be near r
            let gain = unc / cod;
            assert!(gain > 0.7 * r as f64, "r={r}: gain {gain}");
        }
    }

    #[test]
    fn r_equals_one_single_naive_has_no_update_cost() {
        let g = er(100, 0.1, &mut DetRng::seed(45));
        let alloc = Allocation::single(100, 5);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Uncoded), 2);
        assert_eq!(report.iterations[0].times.update_s, 0.0);
        assert_eq!(report.iterations[0].update.messages, 0);
    }

    #[test]
    fn combined_schemes_match_single_machine() {
        let g = er(140, 0.2, &mut DetRng::seed(47));
        let alloc = Allocation::er_scheme(140, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let want = run_single_machine(&prog, &g, 4);
        for scheme in [Scheme::CodedCombined, Scheme::UncodedCombined] {
            let report = run_rust(&job, &cfg(scheme), 4);
            for (a, b) in report.final_state.iter().zip(&want) {
                assert!((a - b).abs() < 1e-13, "{scheme}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn combined_coded_load_below_plain_coded_on_dense_graph() {
        let g = er(200, 0.4, &mut DetRng::seed(48));
        let alloc = Allocation::er_scheme(200, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let plain = run_rust(&job, &cfg(Scheme::Coded), 1).iterations[0]
            .shuffle
            .normalized(200);
        let comb = run_rust(&job, &cfg(Scheme::CodedCombined), 1).iterations[0]
            .shuffle
            .normalized(200);
        assert!(comb < plain / 3.0, "combined {comb} vs plain {plain}");
    }

    #[test]
    fn combined_sssp_min_aggregates_correctly() {
        // min is a valid combiner monoid too
        let g = er(100, 0.15, &mut DetRng::seed(49));
        let alloc = Allocation::er_scheme(100, 4, 2);
        let prog = Sssp::hashed(3);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let want = run_single_machine(&prog, &g, 6);
        let report = run_rust(&job, &cfg(Scheme::CodedCombined), 6);
        for (a, b) in report.final_state.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn run_until_stops_at_tolerance() {
        let g = er(150, 0.1, &mut DetRng::seed(50));
        let alloc = Allocation::er_scheme(150, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let (report, used) = run_until(
            &job,
            &cfg(Scheme::Coded),
            1e-10,
            200,
            &mut Backend::Rust,
        );
        assert!(used < 200, "should converge well before the cap");
        assert!(used > 3, "should take a few iterations");
        assert_eq!(report.iterations.len(), used);
        // converged: one more iteration barely moves
        let more = run_single_machine(&prog, &g, used + 1);
        let resid: f64 = report
            .final_state
            .iter()
            .zip(&more)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(resid < 1e-8, "resid {resid}");
    }

    #[test]
    fn phase_times_populated() {
        let g = er(200, 0.15, &mut DetRng::seed(46));
        let alloc = Allocation::er_scheme(200, 5, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let report = run_rust(&job, &cfg(Scheme::Coded), 1);
        let t = &report.iterations[0].times;
        assert!(t.map_s > 0.0 && t.shuffle_s > 0.0 && t.reduce_s > 0.0);
        assert!(t.encode_s > 0.0 && t.decode_s > 0.0);
        assert!(t.update_s > 0.0);
        assert!(report.iterations[0].wall_s > 0.0);
    }
}
