//! The L3 coordinator: cluster orchestration for coded graph analytics.
//!
//! * [`config`] — scheme selection, time model, engine options.
//! * [`metrics`] — phase times, loads, job reports (the figures' data).
//! * [`engine`] — the deterministic phase engine: flat-arena shuffle
//!   plans, a reusable [`EngineScratch`] (zero-allocation steady-state
//!   iterations), and rayon-parallel phases with bit-identical results.
//! * [`cluster`] — the threaded leader/worker driver (real channels, real
//!   per-worker decode; same phase functions as the engine).

pub mod cluster;
pub mod config;
pub mod engine;
pub mod metrics;

pub use config::{EngineConfig, Scheme, TimeModel};
pub use engine::{
    measure_loads, measure_loads_prepared, prepare, run, run_iteration, run_iteration_scratch,
    run_rust, Backend, EngineScratch, Job, PreparedJob, XlaKind,
};
pub use metrics::{IterationMetrics, JobReport, PhaseTimes};
