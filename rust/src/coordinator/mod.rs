//! The L3 coordinator: cluster orchestration for coded graph analytics.
//!
//! * [`config`] — scheme selection, time model, engine options.
//! * [`metrics`] — phase times, loads, job reports (the figures' data).
//! * [`engine`] — the deterministic phase engine: flat-arena shuffle
//!   plans, a reusable [`EngineScratch`] (zero-allocation steady-state
//!   iterations), rayon-parallel phases with bit-identical results, the
//!   precomputed global routing tables the leader replays
//!   ([`PreparedJob`]), and the per-worker shard the cluster workers
//!   consume instead ([`PreparedWorker`] via [`prepare_worker`]).
//! * [`cluster`] — the leader/worker driver over the pluggable
//!   [`transport`](crate::transport) layer (wire-format frames, in-proc
//!   rings, a localhost TCP mesh, or one process-separated TCP endpoint
//!   per OS process; real per-worker encode/decode, results
//!   bit-identical to the engine).
//! * [`spec`] — serializable job specs: the single line the bootstrap
//!   rendezvous ships so worker processes can deterministically rebuild
//!   graph, allocation, program, and shuffle plan.

pub mod cluster;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod spec;

pub use cluster::{run_cluster, run_cluster_on, run_leader, run_worker};
pub use config::{EngineConfig, Scheme, TimeModel};
pub use spec::{AllocKind, BuiltJob, GraphKind, GraphSpec, JobSpec, ProgramSpec};
pub use engine::{
    measure_loads, measure_loads_prepared, prepare, prepare_worker, run, run_iteration,
    run_iteration_scratch, run_rust, Backend, EngineScratch, Job, PreparedJob, PreparedWorker,
    XlaKind,
};
pub use metrics::{IterationMetrics, JobReport, PhaseTimes};
