//! The L3 coordinator: cluster orchestration for coded graph analytics.
//!
//! * [`config`] — scheme selection, time model, engine options.
//! * [`metrics`] — phase times, loads, job reports (the figures' data).
//! * [`exec`] — **the one worker core** (PR 5): [`WorkerCore`] owns all
//!   steady-state per-worker iteration state and drives the canonical
//!   phase machine (encode → stage sends → ingest frames → decode →
//!   fold → write-back) against the small [`Fabric`] trait; every
//!   driver below plugs in a fabric instead of re-implementing the
//!   algorithm.
//! * [`engine`] — the deterministic phase engine: `K` worker cores over
//!   the in-memory [`DirectFabric`] plus the accounting replay, in a
//!   reusable [`EngineScratch`] (zero-allocation steady-state
//!   iterations, rayon fan-out over cores with bit-identical results);
//!   also the precomputed global tables the leader replays
//!   ([`PreparedJob`]) and the per-worker shard every core consumes
//!   ([`PreparedWorker`] via [`prepare_worker`]).
//! * [`cluster`] — the leader/worker driver over the pluggable
//!   [`transport`](crate::transport) layer (wire-format frames, in-proc
//!   rings, a localhost TCP mesh, or one process-separated TCP endpoint
//!   per OS process): one core per worker over a [`TransportFabric`],
//!   results bit-identical to the engine. Includes the degraded-mode
//!   recovery protocol (PR 6, cascading since PR 9): survive up to
//!   `r − 1` worker losses — adopters included — by re-planning onto
//!   surviving replicas across a chain of recovery epochs, with
//!   straggler deadlines, periodic checkpoints, and typed resumable
//!   aborts past tolerance.
//! * [`sim`] — the deterministic virtual-time fabric (PR 8): `K` worker
//!   cores over a frame-stepped virtual clock with per-link
//!   latency/bandwidth, seeded stragglers, and failure replay at `K` in
//!   the thousands; results bit-identical to the engine, span timelines
//!   bit-identical across same-seed runs.
//! * [`spec`] — serializable job specs: the single line the bootstrap
//!   rendezvous ships so worker processes can deterministically rebuild
//!   graph, allocation, program, and shuffle plan.

pub mod cluster;
pub mod config;
pub mod engine;
pub mod exec;
pub mod metrics;
pub mod sim;
pub mod spec;

pub use cluster::{
    mesh_ring_capacities, run_cluster, run_cluster_net, run_cluster_on, run_cluster_on_with,
    run_leader, run_leader_with, run_worker, run_worker_with, try_run_cluster_net,
    try_run_cluster_on, try_run_cluster_on_with, CheckpointCfg, ClusterError, RunOpts, WorkerOpts,
};
pub use config::{EngineConfig, FabricKind, FailWorker, Scheme, TimeModel};
pub use exec::{DirectFabric, Fabric, PipelinedFabric, TransportFabric, WireFabric, WorkerCore};
pub use spec::{AllocKind, BuiltJob, Checkpoint, GraphKind, GraphSpec, JobSpec, ProgramSpec};
pub use engine::{
    measure_loads, measure_loads_prepared, prepare, prepare_worker, run, run_iteration_scratch,
    run_rust, Backend, EngineScratch, Job, PreparedJob, PreparedWorker, XlaKind,
};
pub use metrics::{IterationMetrics, JobReport, PhaseTimes, RecoveryStats};
pub use sim::{
    clean_iteration_load, run_sim, RecoveryPolicy, SimConfig, SimIterRecord, SimReport,
    StragglerDist,
};
