//! The L3 coordinator: cluster orchestration for coded graph analytics.
//!
//! * [`config`] — scheme selection, time model, engine options.
//! * [`metrics`] — phase times, loads, job reports (the figures' data).
//! * [`engine`] — the deterministic single-process phase engine.
//! * [`cluster`] — the threaded leader/worker driver (real channels, real
//!   per-worker decode; same phase functions as the engine).

pub mod cluster;
pub mod config;
pub mod engine;
pub mod metrics;

pub use config::{EngineConfig, Scheme, TimeModel};
pub use engine::{measure_loads, prepare, run, run_iteration, run_rust, Backend, Job, XlaKind};
pub use metrics::{IterationMetrics, JobReport, PhaseTimes};
