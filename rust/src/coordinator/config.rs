//! Job configuration: scheme selection and the execution-time model.

use crate::network::BusConfig;
use crate::WorkerId;

/// Which Shuffle scheme to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// The paper's coded multicast scheme (§IV-A).
    Coded,
    /// The uncoded unicast baseline.
    Uncoded,
    /// Coded scheme over *combined* (pre-aggregated) IVs — the §VII / [18]
    /// extension: one IV per (Reducer, batch) instead of per edge, XOR
    /// multicast on top. Engine driver only.
    CodedCombined,
    /// Uncoded unicast of combined IVs (Pregel-style combiners alone).
    UncodedCombined,
}

impl Scheme {
    /// Does this scheme pre-aggregate IVs per (Reducer, batch)?
    pub fn is_combined(&self) -> bool {
        matches!(self, Scheme::CodedCombined | Scheme::UncodedCombined)
    }

    /// Does this scheme use the coded multicast groups?
    pub fn is_coded(&self) -> bool {
        matches!(self, Scheme::Coded | Scheme::CodedCombined)
    }

    /// The stable CLI / job-spec token ([`std::fmt::Display`] renders a
    /// prettier form for tables; this one parses back via [`std::str::FromStr`]).
    pub fn token(&self) -> &'static str {
        match self {
            Scheme::Coded => "coded",
            Scheme::Uncoded => "uncoded",
            Scheme::CodedCombined => "coded-combined",
            Scheme::UncodedCombined => "uncoded-combined",
        }
    }
}

impl std::str::FromStr for Scheme {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "coded" => Scheme::Coded,
            "uncoded" => Scheme::Uncoded,
            "coded-combined" => Scheme::CodedCombined,
            "uncoded-combined" => Scheme::UncodedCombined,
            other => {
                return Err(format!(
                    "unknown scheme {other:?} (expected coded|uncoded|coded-combined|uncoded-combined)"
                ))
            }
        })
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scheme::Coded => write!(f, "coded"),
            Scheme::Uncoded => write!(f, "uncoded"),
            Scheme::CodedCombined => write!(f, "coded+combiners"),
            Scheme::UncodedCombined => write!(f, "uncoded+combiners"),
        }
    }
}

/// Per-operation compute-time model used for the *simulated* phase times
/// (the engine also reports real wall times; the model exists so scenario
/// benches can reproduce the paper's testbed balance, where Map was
/// Python-speed and Shuffle rode a 100 Mbps NIC — see DESIGN.md §2).
///
/// Defaults are calibrated from the paper's Remark 10 numbers for
/// Scenario 2 (`T_map = 1.649 s` at `r = 1`, `n = 12600`, `p = 0.3`,
/// `K = 10`: ~4.76M directed Map evaluations *per worker* — Map runs in
/// parallel — → ~350 ns each, i.e. mpi4py/Python interpreter speed).
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    /// Seconds per Map evaluation (one IV: one edge endpoint).
    pub map_edge_s: f64,
    /// Seconds per Reduce combine (one IV folded).
    pub reduce_iv_s: f64,
    /// Seconds per table byte XORed during Encode.
    pub encode_byte_s: f64,
    /// Seconds per received byte cancelled during Decode (the decoder
    /// re-derives r-1 segments per byte, hence ~r x encode cost; the
    /// engine multiplies by r).
    pub decode_byte_s: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        Self::python_speed()
    }
}

impl TimeModel {
    /// A "compute is free" model — isolates the communication trade-off.
    pub fn zero() -> Self {
        Self { map_edge_s: 0.0, reduce_iv_s: 0.0, encode_byte_s: 0.0, decode_byte_s: 0.0 }
    }

    /// Python-speed model matching the paper's mpi4py implementation
    /// (interpreted per-edge loops; Remark 10 calibration: ~350 ns per Map
    /// evaluation per worker).
    pub fn python_speed() -> Self {
        Self {
            map_edge_s: 350e-9,
            reduce_iv_s: 200e-9,
            encode_byte_s: 5e-9,
            decode_byte_s: 5e-9,
        }
    }

    /// Compiled-rust speed (what this implementation actually measures on
    /// its own hot loops; used to contrast against [`python_speed`]).
    pub fn rust_speed() -> Self {
        Self {
            map_edge_s: 10e-9,
            reduce_iv_s: 6e-9,
            encode_byte_s: 0.5e-9,
            decode_byte_s: 0.5e-9,
        }
    }
}

/// How the leader picks the *adopter* — the survivor that hosts the
/// ghost cores of every dead worker — at each recovery epoch. When the
/// current adopter itself dies, the next epoch's choice re-runs the same
/// policy over the remaining survivors and the whole ghost set cascades
/// onto it. Shared by the cluster driver (`--policy` on `cluster`) and
/// the sim fabric (`--policy` on `simulate`); both policies finish
/// bit-identical to the clean run — the policy only moves *where* the
/// recovered work lands, never its values.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Lowest surviving worker id (the PR 6 behavior): deterministic and
    /// cheap, but piles every ghost onto one end of the id space.
    #[default]
    LowestSurvivor,
    /// Survivor with the smallest static load (mapped + reduce edges),
    /// ties broken by id: spreads adopted work toward the lightest
    /// worker the plan produced.
    LoadSpread,
}

impl RecoveryPolicy {
    /// The stable CLI token.
    pub fn token(&self) -> &'static str {
        match self {
            RecoveryPolicy::LowestSurvivor => "lowest",
            RecoveryPolicy::LoadSpread => "spread",
        }
    }
}

impl std::str::FromStr for RecoveryPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "lowest" => Ok(RecoveryPolicy::LowestSurvivor),
            "spread" => Ok(RecoveryPolicy::LoadSpread),
            other => Err(format!("unknown recovery policy {other:?} (expected lowest|spread)")),
        }
    }
}

impl std::fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.token())
    }
}

/// Which worker-side fabric the cluster drivers plug into the
/// [`WorkerCore`](super::WorkerCore) (`cluster --fabric`). Both are
/// bit-identical — the fabric only changes *when* staged bytes reach
/// the wire, never their values or order (pinned in
/// `tests/driver_matrix.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FabricKind {
    /// Synchronous flush: `complete_sends` writes every staged buffer
    /// before returning (the PR 4 batched wire path) — the bit-identity
    /// oracle.
    #[default]
    Sync,
    /// Asynchronous hand-off: `complete_sends` hands the staged buffers
    /// to the transport's writer thread and returns immediately, so the
    /// iteration's flush overlaps its own ingest/decode and the next
    /// iteration's encode (`Transport::flush_begin`; PR 10). Falls back
    /// to a synchronous flush on transports without an async path (the
    /// in-process rings deliver eagerly anyway).
    Pipelined,
}

impl FabricKind {
    /// The stable CLI token.
    pub fn token(&self) -> &'static str {
        match self {
            FabricKind::Sync => "sync",
            FabricKind::Pipelined => "pipelined",
        }
    }
}

impl std::str::FromStr for FabricKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sync" => Ok(FabricKind::Sync),
            "pipelined" => Ok(FabricKind::Pipelined),
            other => Err(format!("unknown fabric {other:?} (expected sync|pipelined)")),
        }
    }
}

impl std::fmt::Display for FabricKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.token())
    }
}

/// Fault injection: kill one worker at the top of one iteration
/// (`--fail-worker ID@ITER`). The worker tears its endpoint down
/// abnormally — peers observe a typed `PeerDown` — and exits cleanly, so
/// the surviving cluster's recovery path is what gets exercised.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FailWorker {
    /// Worker endpoint id (`0..K`).
    pub worker: WorkerId,
    /// 0-based iteration at whose start the worker dies.
    pub at_iter: usize,
}

impl std::str::FromStr for FailWorker {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (w, t) = s
            .split_once('@')
            .ok_or_else(|| format!("bad fail spec {s:?} (expected ID@ITER, e.g. 2@1)"))?;
        Ok(FailWorker {
            worker: w.parse().map_err(|e| format!("bad worker id {w:?}: {e}"))?,
            at_iter: t.parse().map_err(|e| format!("bad iteration {t:?}: {e}"))?,
        })
    }
}

impl std::fmt::Display for FailWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@{}", self.worker, self.at_iter)
    }
}

/// Full engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub scheme: Scheme,
    pub bus: BusConfig,
    pub time: TimeModel,
    /// Account the post-Reduce state write-back to Mappers (needed for
    /// iterative jobs; the paper's coded runs pay it, `r = 1` does not).
    pub account_state_update: bool,
    /// Bit-exact validation of every recovered IV against a direct Map
    /// evaluation (O(needed IVs) extra work; on in tests, off in benches).
    pub validate: bool,
    /// Run Map / Encode / Decode / Reduce across threads (rayon). Results
    /// and metrics are bit-identical to the serial path — all writes go
    /// to disjoint precomputed arena regions and every floating-point
    /// merge replays in a fixed serial order — so this is purely a
    /// wall-clock knob. Ignored (serial) when the `parallel` feature is
    /// compiled out.
    pub parallel: bool,
    /// Fault injection for the cluster drivers: up to two workers that
    /// die at the top of a given iteration. Ignored by the engine.
    pub fail_workers: [Option<FailWorker>; 2],
    /// Adopter choice at each recovery epoch (cluster drivers and the
    /// sim fabric). Leader-side state: workers follow the adopter id the
    /// `Recover` frame carries instead of recomputing the policy.
    pub policy: RecoveryPolicy,
    /// Per-phase receive deadline in milliseconds for the cluster
    /// drivers. The leader treats a worker producing nothing for this
    /// long as dead; workers use it as the straggler cutoff (proceed to
    /// decode once every missing coded frame is pure padding). `None`
    /// waits forever.
    pub phase_deadline_ms: Option<u64>,
    /// Record flight-recorder phase spans ([`crate::obs`]) on every
    /// core. On by default: recording is allocation-free and the
    /// `observer_overhead` bench section pins its cost under 5%.
    /// Traced and untraced runs are bit-identical on every driver
    /// (pinned in `tests/driver_matrix.rs`).
    pub trace: bool,
    /// Worker-side fabric for the cluster drivers (`--fabric`). The
    /// engine and sim drivers ignore it (the sim has its own
    /// `pipelined` knob on [`super::sim::SimConfig`]). Bit-identity
    /// across fabrics is pinned in `tests/driver_matrix.rs`.
    pub fabric: FabricKind,
    /// Max in-flight flush generations for [`FabricKind::Pipelined`]
    /// (`--pipeline-depth`). 1 = classic double buffer: the worker
    /// stages iteration t+1 into fresh buffers while the writer thread
    /// drains iteration t; staging t+2 blocks until t is on the wire.
    /// Ignored by [`FabricKind::Sync`].
    pub pipeline_depth: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            scheme: Scheme::Coded,
            bus: BusConfig::default(),
            time: TimeModel::default(),
            account_state_update: true,
            validate: false,
            parallel: true,
            fail_workers: [None, None],
            policy: RecoveryPolicy::default(),
            phase_deadline_ms: None,
            trace: true,
            fabric: FabricKind::default(),
            pipeline_depth: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = EngineConfig::default();
        assert_eq!(c.scheme, Scheme::Coded);
        assert!(c.time.map_edge_s > 0.0);
        assert!(!c.validate);
        assert!(c.trace, "the flight recorder is on by default");
    }

    #[test]
    fn scheme_display() {
        assert_eq!(Scheme::Coded.to_string(), "coded");
        assert_eq!(Scheme::Uncoded.to_string(), "uncoded");
    }

    #[test]
    fn scheme_token_parse_roundtrip() {
        for s in [
            Scheme::Coded,
            Scheme::Uncoded,
            Scheme::CodedCombined,
            Scheme::UncodedCombined,
        ] {
            assert_eq!(s.token().parse::<Scheme>().unwrap(), s);
        }
        assert!("laplace".parse::<Scheme>().is_err());
    }

    #[test]
    fn fail_worker_parse_roundtrip() {
        let f: FailWorker = "2@1".parse().unwrap();
        assert_eq!(f, FailWorker { worker: 2, at_iter: 1 });
        assert_eq!(f.to_string(), "2@1");
        assert!("2".parse::<FailWorker>().is_err());
        assert!("x@1".parse::<FailWorker>().is_err());
        assert!("2@y".parse::<FailWorker>().is_err());
    }

    #[test]
    fn fabric_token_parse_roundtrip() {
        for f in [FabricKind::Sync, FabricKind::Pipelined] {
            assert_eq!(f.token().parse::<FabricKind>().unwrap(), f);
            assert_eq!(f.to_string(), f.token());
        }
        assert!("mio".parse::<FabricKind>().is_err());
        assert_eq!(FabricKind::default(), FabricKind::Sync);
        assert_eq!(EngineConfig::default().pipeline_depth, 1);
    }

    #[test]
    fn zero_model_is_zero() {
        let t = TimeModel::zero();
        assert_eq!(t.map_edge_s + t.reduce_iv_s + t.encode_byte_s + t.decode_byte_s, 0.0);
    }
}
