//! The single per-worker execution core (PR 5): one implementation of
//! the paper's per-server algorithm, shared by every driver.
//!
//! The paper's scheme (§IV) is *one* algorithm per server — Map the
//! stored batches, encode per multicast group, decode received messages
//! with locally-computed IVs, Reduce — yet this repo used to implement
//! it twice: the engine ran it group-centrically in one process, the
//! cluster driver sender-centrically over the transport. This module is
//! the merge point. [`WorkerCore`] owns **all** steady-state per-worker
//! iteration state (the worker's [`PreparedWorker`] shard, derived
//! routing, scratch arenas, the per-iteration `qbits` mapper-once cache,
//! the validated-IV tally) and drives the canonical phase machine
//!
//! ```text
//! encode → stage sends → ingest frames → decode → fold → write-back
//! ```
//!
//! against a small [`Fabric`] trait — the core's only view of the
//! outside world. The phase *order* is canonical, but since PR 10 it is
//! no longer a strict wall-clock barrier: a fabric may keep iteration
//! t's flush in flight while the core runs t's ingest/decode and even
//! t+1's encode — only write-back mutates state, and it consumes
//! nothing that is still on the wire. Three wire fabrics exist:
//!
//! * [`DirectFabric`] — in-memory frame handoff between the `K` cores of
//!   one process. Each core stages its serialized frames (with receiver
//!   lists) into its own send log; after a phase barrier every core
//!   ingests, from all logs, exactly the frames addressed to it. The
//!   engine fans the cores out over rayon — staging writes only the
//!   core's own log and ingesting only reads the logs, so both phases
//!   parallelize without synchronization and stay bit-identical at any
//!   thread count.
//! * [`TransportFabric`] — a thin adapter over the
//!   [`Transport`](crate::transport::Transport) buffered surface: stages
//!   ride the batched wire path, `complete_sends` flushes once per peer
//!   and emits the `SendDone` tally frame, and `recv_data` filters the
//!   leader's `StartReduce` barrier out of the inbound stream.
//! * [`PipelinedFabric`] (PR 10) — the same adapter with the flush moved
//!   onto the transport's writer thread: `complete_sends` hands the
//!   staged buffers over as one depth-bounded *generation* and returns,
//!   overlapping wire time with compute. Bit-identical to both fabrics
//!   above (pinned in `tests/driver_matrix.rs`).
//!
//! Both fabrics move the *same serialized frames* ([`frame`]), so a
//! frame's bytes — and therefore the wire accounting — are identical
//! whether they cross a rayon task boundary, an in-process ring, or a
//! real socket. Results are bit-identical across drivers because the
//! core folds local and received IVs in one canonical order (local Map
//! values, then groups ascending by wire id, then transfers ascending).
//!
//! ## Steady-state allocation (audited)
//!
//! After the first iteration warms every buffer, the core's data path
//! allocates nothing: encode reuses `vals`/`cols`/`gvals` arenas and one
//! frame buffer, staging appends into capacity-retained fabric buffers,
//! ingest copies into preallocated arenas, and decode/fold write into
//! fixed slices. `tests/zero_alloc.rs` asserts this under a counting
//! allocator for the core driven by **both** fabrics (serial path).

use crate::allocation::Allocation;
use crate::graph::csr::{Csr, Vertex};
use crate::mapreduce::program::VertexProgram;
use crate::obs::{now_ns, Phase, SpanRing, TraceSpan};
use crate::shuffle::coded::{encode_sender_into, eval_rows_except, segment_index};
use crate::shuffle::combined::combined_value;
use crate::shuffle::decoder::decode_sender_into;
#[cfg(feature = "xla")]
use crate::shuffle::decoder::RecoveredIv;
use crate::shuffle::plan::surviving_donor;
use crate::shuffle::segments::seg_bytes;
use crate::transport::frame::{self, Frame, FrameKind};
use crate::transport::Transport;
use crate::WorkerId;

use std::collections::VecDeque;

use super::engine::{Job, PreparedWorker};

/// The execution core's view of the outside world: where staged frames
/// go and where inbound data frames come from. Implementations decide
/// what a "send" physically is (an in-memory log entry, a buffered
/// socket write); the core only ever sees serialized [`frame`]s.
///
/// Contract: during [`WorkerCore::stage_sends`] the core calls only the
/// staging half (`stage_multicast` / `stage_unicast`, then exactly one
/// `complete_sends` with the iteration's data tally); during
/// [`WorkerCore::ingest_all`] it calls only `recv_data`. A fabric
/// endpoint that serves a single phase may leave the other half
/// unreachable.
pub trait Fabric {
    /// Stage one serialized data frame toward every endpoint in
    /// `receivers` (one logical transmission, like one bus slot).
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]);

    /// Stage one serialized data frame toward a single endpoint.
    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]);

    /// All of this iteration's frames are staged: push them toward the
    /// peers and hand over the data tally (`frames` transmissions,
    /// `bytes` serialized bytes) to whoever accounts for it — the
    /// transport fabric flushes the batched wire path and emits the
    /// `SendDone` barrier frame; the direct fabric records the tally for
    /// the engine's model-vs-staged cross-check.
    fn complete_sends(&mut self, frames: u32, bytes: u64);

    /// Block for the next inbound *data* frame, filling `buf` (contents
    /// replaced, capacity recycled). Control traffic is fabric-internal.
    /// Returns `false` when no frame can ever arrive again — the core
    /// treats that as a failed peer and panics.
    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool;

    /// Which flight-recorder phase the `complete_sends` window belongs
    /// to. Synchronous fabrics spend it writing sockets —
    /// [`Phase::Flush`] (the default). [`PipelinedFabric`] only hands
    /// buffers to the transport's writer thread there, so it reports
    /// [`Phase::FlushWait`]: the span measures hand-off plus any depth
    /// backpressure, while the physical writes overlap the phases that
    /// follow. One method instead of two spans keeps the per-core
    /// timeline non-overlapping (a chrome-trace invariant the obs tests
    /// pin).
    fn flush_phase(&self) -> Phase {
        Phase::Flush
    }
}

/// One worker's execution core: the canonical per-server iteration state
/// machine, owning the worker's [`PreparedWorker`] shard plus every
/// steady-state buffer. Drivers differ only in how they sequence the
/// phases and which [`Fabric`] they plug in.
pub struct WorkerCore {
    prep: PreparedWorker,
    r: usize,
    sb: usize,
    combined: bool,
    /// Does the program's Map ignore the destination? If so, `qbits`
    /// caches one value per mapped vertex per iteration instead of a
    /// dyn-dispatched `map` call per pair (the mapper-once fast path).
    src_only: bool,
    /// Wire ids of the groups this worker decodes, ascending — 1:1 with
    /// `prep.recv_groups()` (inbound frame routing).
    my_gids: Vec<u64>,
    /// Member index of this worker within each recv group.
    my_row_idx: Vec<usize>,
    garena_off: Vec<usize>,
    gvals_off: Vec<usize>,
    /// Wire ids of the transfers this worker receives, ascending, and
    /// their IV-arena offsets (1:1 with `prep.unc_recv()`).
    my_unc_ids: Vec<u64>,
    unc_off: Vec<usize>,
    expect_coded: usize,
    expect_unc: usize,
    // -- steady-state scratch (allocated once; see the module audit) --
    /// Per-mapper Map-value cache (`src_only` fast path), refreshed once
    /// per iteration at stage time (state is frozen until write-back).
    /// Indexed by global vertex id for O(1) reads on the encode and
    /// fold hot paths, so it is `n`-sized per core even though only the
    /// worker's mapped entries are ever touched — a per-worker process
    /// always paid that, and the engine driver now pays `K·n` words for
    /// its `K` in-process cores (a deliberate memory-for-speed trade at
    /// this repo's scales; a shard-indexed cache would need a
    /// `batch_of` lookup per read — O(1) since PR 10, but still an extra
    /// dependent load — see the ROADMAP standing note).
    qbits: Vec<u64>,
    vals: Vec<u64>,
    cols: Vec<u64>,
    bits: Vec<u64>,
    /// Received coded columns, `members * my_len` per group, sender-major.
    garena: Vec<u64>,
    /// Group IV values for the groups this worker decodes, evaluated
    /// once per iteration during [`WorkerCore::stage_sends`] (the
    /// sender-side skip index equals the receiver-side one, and state is
    /// frozen until write-back) and reused by decode. Recv-groups this
    /// worker does not send in have all other rows empty, so their
    /// (stale) entries are never read during decode.
    gvals: Vec<u64>,
    /// Received uncoded IV bits, canonical transfer order.
    unc_arena: Vec<u64>,
    ivbits: Vec<u64>,
    accs: Vec<f64>,
    next_bits: Vec<u64>,
    receivers: Vec<WorkerId>,
    sendbuf: Vec<u8>,
    rbuf: Vec<u8>,
    got_coded: usize,
    got_unc: usize,
    last_validated: u32,
    // -- degraded-mode state (inert until the leader ships a `Recover`) --
    /// Recovery generation, stamped into every staged frame so receivers
    /// can drop pre-failure stragglers and stash post-restart early birds.
    epoch: u8,
    /// Dead workers, ascending (leader-authoritative).
    dead: Vec<WorkerId>,
    /// Physical endpoint adopting each logical worker's frames —
    /// identity for live workers, the adopter for dead ones.
    route: Vec<WorkerId>,
    /// Per recv slot: does the group contain a dead member? A degraded
    /// group carries no coded frames — one raw [`FrameKind::RecoverRow`]
    /// from a surviving donor replaces them.
    degraded: Vec<bool>,
    /// Which senders delivered this iteration, per `(slot, member)`
    /// (`slot * (r + 1) + s_idx`). Duplicates — a straggler's late
    /// delivery racing its next iteration down the same FIFO connection
    /// — overwrite the arena without re-counting.
    seen: Vec<bool>,
    /// Straggler frames skipped by the last deadline cutoff.
    skipped: u32,
    /// Raw-row scratch for degraded-group donor duties.
    raw_row: Vec<u64>,
    /// Flight-recorder span ring ([`crate::obs`]): preallocated at
    /// construction, written in place on the hot path (no steady-state
    /// allocation — covered by the `tests/zero_alloc.rs` audit).
    obs: SpanRing,
}

/// The IV value both schemes and the decoder share — a pure function of
/// `(i, j, state)`. For combined schemes the "mapper" slot carries a
/// batch index and the value is the per-(Reducer, batch) pre-aggregate;
/// every evaluation site in the core only touches batches the worker
/// Maps, so a cluster worker's NaN state poison never leaks into
/// results (engine cores see the full state and never trip the check).
#[inline]
fn iv_value(
    g: &Csr,
    alloc: &Allocation,
    prog: &dyn VertexProgram,
    state: &[f64],
    combined: bool,
    i: Vertex,
    j: Vertex,
) -> u64 {
    if combined {
        combined_value(g, alloc, prog, state, i, j as usize).to_bits()
    } else {
        let s = state[j as usize];
        debug_assert!(!s.is_nan(), "worker read unowned state {j}");
        prog.map(i, j, s, g).to_bits()
    }
}

impl WorkerCore {
    /// Build the core for one worker: derived routing plus every
    /// steady-state buffer, sized from the shard (`job` must be the job
    /// the shard was prepared from).
    pub fn new(job: &Job<'_>, prep: PreparedWorker) -> WorkerCore {
        let (g, alloc, prog) = (job.graph, job.alloc, job.program);
        let n = g.n();
        let r = alloc.r;
        let me = prep.me;
        let plan = &prep.plan;
        let rows = &alloc.reduce_sets[me as usize];

        // scratch sizing: max value-arena / column counts over the groups
        // this worker encodes or decodes (shard-local indices throughout)
        let mut vals_cap = 0usize;
        let mut cols_cap = 0usize;
        for &(l, si) in prep.send_plan() {
            vals_cap = vals_cap.max(plan.group(l as usize).total_ivs());
            cols_cap = cols_cap.max(plan.sender_cols(l as usize)[si as usize] as usize);
        }
        let my_groups = prep.recv_groups();
        let mut my_gids = Vec::with_capacity(my_groups.len());
        let mut my_row_idx = Vec::with_capacity(my_groups.len());
        let mut garena_off = Vec::with_capacity(my_groups.len());
        let mut gvals_off = Vec::with_capacity(my_groups.len());
        let mut garena_len = 0usize;
        let mut gvals_len = 0usize;
        let mut bits_cap = 0usize;
        for &l in my_groups {
            let group = plan.group(l as usize);
            let m_idx = group.member_index(me).expect("routing: not a member");
            let my_len = group.row_len(m_idx);
            bits_cap = bits_cap.max(my_len);
            my_gids.push(plan.wire_id(l as usize));
            my_row_idx.push(m_idx);
            garena_off.push(garena_len);
            garena_len += group.members() * my_len;
            gvals_off.push(gvals_len);
            gvals_len += group.total_ivs();
        }
        let my_unc_recv = prep.unc_recv();
        let mut my_unc_ids = Vec::with_capacity(my_unc_recv.len());
        let mut unc_off = Vec::with_capacity(my_unc_recv.len());
        let mut unc_len = 0usize;
        for &ti in my_unc_recv {
            my_unc_ids.push(prep.transfer_ids[ti as usize]);
            unc_off.push(unc_len);
            unc_len += prep.transfers[ti as usize].ivs.len();
        }
        let ivbits_cap = prep
            .unc_sends()
            .iter()
            .map(|&ti| prep.transfers[ti as usize].ivs.len())
            .max()
            .unwrap_or(0);
        let combined = prep.scheme.is_combined();
        let src_only = !combined && !prog.map_depends_on_dst();
        let expect_coded = prep.expect_coded();
        let expect_unc = prep.expect_unc();
        let n_slots = my_gids.len();

        WorkerCore {
            prep,
            r,
            sb: seg_bytes(r),
            combined,
            src_only,
            my_gids,
            my_row_idx,
            garena_off,
            gvals_off,
            my_unc_ids,
            unc_off,
            expect_coded,
            expect_unc,
            qbits: vec![0u64; if src_only { n } else { 0 }],
            vals: vec![0u64; vals_cap],
            cols: vec![0u64; cols_cap],
            bits: vec![0u64; bits_cap],
            garena: vec![0u64; garena_len],
            gvals: vec![0u64; gvals_len],
            unc_arena: vec![0u64; unc_len],
            ivbits: Vec::with_capacity(ivbits_cap),
            accs: vec![0.0f64; rows.len()],
            next_bits: vec![0u64; rows.len()],
            receivers: Vec::with_capacity(r + 1),
            sendbuf: Vec::new(),
            rbuf: Vec::new(),
            got_coded: 0,
            got_unc: 0,
            last_validated: 0,
            epoch: 0,
            dead: Vec::new(),
            route: (0..alloc.k as WorkerId).collect(),
            degraded: vec![false; n_slots],
            seen: vec![false; n_slots * (r + 1)],
            skipped: 0,
            raw_row: Vec::new(),
            obs: SpanRing::default(),
        }
    }

    /// The worker this core executes.
    #[inline]
    pub fn me(&self) -> WorkerId {
        self.prep.me
    }

    /// Finalized reduce-set state bits of the last
    /// [`WorkerCore::decode_and_fold`], in the worker's canonical
    /// reduce-set order.
    #[inline]
    pub fn next_bits(&self) -> &[u64] {
        &self.next_bits
    }

    /// Recovered-and-ownership-checked IV count of the last
    /// [`WorkerCore::decode_and_fold`].
    #[inline]
    pub fn last_validated(&self) -> u32 {
        self.last_validated
    }

    /// The worker's prepared shard — read access for drivers that derive
    /// recovery duties from it.
    #[inline]
    pub fn prep(&self) -> &PreparedWorker {
        &self.prep
    }

    /// Current recovery generation (zero until a failure).
    #[inline]
    pub fn epoch(&self) -> u8 {
        self.epoch
    }

    /// Straggler frames skipped by this iteration's deadline cutoff
    /// (reset by [`WorkerCore::reset_ingest`]).
    #[inline]
    pub fn skipped(&self) -> u32 {
        self.skipped
    }

    /// Turn flight-recorder span recording on or off ([`crate::obs`];
    /// on by default).
    pub fn set_trace(&mut self, on: bool) {
        self.obs.set_enabled(on);
    }

    /// Is the flight recorder recording on this core?
    #[inline]
    pub fn spans_enabled(&self) -> bool {
        self.obs.enabled()
    }

    /// Tag subsequently recorded spans with iteration `it`.
    pub fn set_trace_iter(&mut self, it: u32) {
        self.obs.set_iter(it);
    }

    /// Record an externally measured span into this core's ring — for
    /// the phase windows the core does not own: the engine's serial
    /// write-back, the cluster worker's own receive loop, and its
    /// state-update application. No-op while tracing is off.
    pub fn note_span(&mut self, phase: Phase, start_ns: u64, dur_ns: u64, bytes: u64, frames: u32) {
        self.obs.record(phase, start_ns, dur_ns, bytes, frames);
    }

    /// Drain this core's recorded spans (oldest first) into `out`,
    /// stamped with the *physical* hosting endpoint `worker` (the core's
    /// own id is the logical tid — they differ for adopted ghost cores).
    /// Returns how many spans the ring overwrote before this drain.
    pub fn drain_spans(&mut self, worker: WorkerId, out: &mut Vec<TraceSpan>) -> u64 {
        self.obs.drain_into(worker, self.prep.me, out)
    }

    /// Extend this core for degraded-mode execution after the leader
    /// declared `dead` (ascending): flag the degraded recv slots,
    /// recompute the per-iteration expectations (a degraded group
    /// delivers one raw [`FrameKind::RecoverRow`] instead of `r` coded
    /// frames; a dead-sender transfer delivers one
    /// [`FrameKind::RecoverPairs`] per surviving donor), derive the
    /// adoption route (dead workers' frames go to the adopter), and
    /// size the raw-row scratch. Callable repeatedly — everything
    /// here is a pure function of `dead`, which is what makes cascading
    /// re-adoption safe: any epoch's call produces the same plan no
    /// matter how many earlier adoptions it replaces. The caller
    /// restarts the iteration afterwards ([`WorkerCore::reset_ingest`]):
    /// state only mutates at write-back, so a partially ingested
    /// iteration is safely re-entrant.
    ///
    /// This convenience form defaults the adopter to the lowest
    /// survivor ([`RecoveryPolicy::LowestSurvivor`] semantics); the
    /// cluster and sim drivers call [`WorkerCore::adopt_with`] with the
    /// leader's policy choice instead.
    ///
    /// [`RecoveryPolicy::LowestSurvivor`]: super::config::RecoveryPolicy::LowestSurvivor
    pub fn adopt(&mut self, job: &Job<'_>, dead: &[WorkerId], epoch: u8) {
        let adopter = (0..job.alloc.k as WorkerId)
            .find(|w| !dead.contains(w))
            .expect("recovery: no survivors");
        self.adopt_with(job, dead, epoch, adopter);
    }

    /// [`WorkerCore::adopt`] with an explicit ghost-placement choice:
    /// every dead worker's frames reroute to `adopter` instead of the
    /// default lowest survivor. All cores of a job must be given the
    /// same adopter — the route is part of the shared recovery plan,
    /// which is why the cluster's `Recover` frame carries the leader's
    /// choice in its `target` field for workers to follow. Used by the
    /// cluster driver's cascade path and by the sim fabric to compare
    /// placement policies (lowest-survivor vs load-spread) at large `K`.
    pub fn adopt_with(&mut self, job: &Job<'_>, dead: &[WorkerId], epoch: u8, adopter: WorkerId) {
        let alloc = job.alloc;
        assert!(!dead.contains(&adopter), "recovery: adopter is dead");
        self.epoch = epoch;
        self.obs.set_epoch(epoch);
        self.dead.clear();
        self.dead.extend_from_slice(dead);
        for (w, hop) in self.route.iter_mut().enumerate() {
            *hop = if dead.contains(&(w as WorkerId)) { adopter } else { w as WorkerId };
        }
        let plan = &self.prep.plan;
        let mut expect_coded = 0usize;
        for (slot, &l) in self.prep.recv_groups().iter().enumerate() {
            let group = plan.group(l as usize);
            let degr = group.servers.iter().any(|s| dead.contains(s));
            self.degraded[slot] = degr;
            expect_coded += if degr { 1 } else { group.members() - 1 };
        }
        self.expect_coded = expect_coded;
        // donor duties may ship any member's row of any degraded group
        let mut raw_cap = 0usize;
        for l in 0..plan.num_groups() {
            let group = plan.group(l);
            if group.servers.iter().any(|s| dead.contains(s)) {
                for mi in 0..group.members() {
                    raw_cap = raw_cap.max(group.row_len(mi));
                }
            }
        }
        if self.raw_row.capacity() < raw_cap {
            self.raw_row.reserve(raw_cap - self.raw_row.capacity());
        }
        let mut expect_unc = 0usize;
        for &ti in self.prep.unc_recv() {
            let t = &self.prep.transfers[ti as usize];
            if dead.contains(&t.sender) {
                // one frame per distinct surviving donor: the lowest live
                // replica of each IV's batch — the exact rule the donors
                // themselves apply in `stage_dead_sender_transfers`
                let mut donors = vec![false; alloc.k];
                for &(_, j) in &t.ivs {
                    let b = if self.combined { j as usize } else { alloc.batch_of(j) };
                    let d = surviving_donor(&alloc.batches[b].servers, t.sender, dead)
                        .expect("recovery: failures exceed the plan's redundancy");
                    donors[d as usize] = true;
                }
                expect_unc += donors.iter().filter(|&&d| d).count();
            } else {
                expect_unc += 1;
            }
        }
        self.expect_unc = expect_unc;
    }

    /// Refill the per-iteration `qbits` mapper cache without staging any
    /// sends — the ghost-core path: an adopted worker contributes no new
    /// transmissions (all its groups are degraded, so donors replace its
    /// traffic), but its local Reduce fold still reads the cache.
    pub fn refresh_local_cache(&mut self, job: &Job<'_>, state: &[f64]) {
        if !self.src_only {
            return;
        }
        let (g, alloc, prog) = (job.graph, job.alloc, job.program);
        let me = self.prep.me;
        // sweep the worker's Mapped ids as a handful of merged contiguous
        // ranges instead of re-deriving per-batch offsets every iteration
        for (lo, hi) in alloc.mapped_ranges(me) {
            for j in lo..hi {
                let s = state[j as usize];
                debug_assert!(!s.is_nan(), "worker {me} mapped-state poison at {j}");
                self.qbits[j as usize] =
                    if g.degree(j) == 0 { 0 } else { prog.map(j, j, s, g).to_bits() };
            }
        }
    }

    /// Deadline cutoff: may this iteration's decode proceed without the
    /// frames still missing? True — after tallying them as skipped —
    /// iff every absent coded contribution is pure padding: segment
    /// `segment_index(s_idx, m_idx)` of the receiver's row lies beyond
    /// the 64-bit value width, so
    /// [`decode_sender_into`](crate::shuffle::decoder::decode_sender_into)
    /// ignores that sender's frame entirely (the receiver effectively
    /// holds the sender's share by construction). Uncoded unicasts and
    /// degraded-group raw rows carry sole copies — never cut off.
    pub fn try_cutoff(&mut self) -> bool {
        if self.got_unc != self.expect_unc {
            return false;
        }
        let mut extra = 0u32;
        for (slot, &l) in self.prep.recv_groups().iter().enumerate() {
            let group = self.prep.plan.group(l as usize);
            let m_idx = self.my_row_idx[slot];
            if self.degraded[slot] {
                if !self.seen[slot * (self.r + 1) + m_idx] {
                    return false; // the raw row is the sole copy
                }
                continue;
            }
            for s_idx in 0..group.members() {
                if s_idx == m_idx || self.seen[slot * (self.r + 1) + s_idx] {
                    continue;
                }
                if segment_index(s_idx, m_idx) * self.sb * 8 < 64 {
                    return false; // a real segment is still missing
                }
                extra += 1;
            }
        }
        self.skipped += extra;
        self.got_coded = self.expect_coded;
        true
    }

    /// Zero the per-iteration ingest tallies, the duplicate-detection
    /// bitmap, and the straggler-skip count: the end of a completed
    /// ingest, or an epoch restart discarding a partial iteration.
    pub fn reset_ingest(&mut self) {
        self.got_coded = 0;
        self.got_unc = 0;
        self.seen.fill(false);
        self.skipped = 0;
    }

    /// Phase 1–2 (encode → stage sends): evaluate this worker's IVs,
    /// encode its coded columns and uncoded batches into wire frames,
    /// stage everything through the fabric, and close the phase with
    /// `complete_sends(frames, bytes)`. When Map ignores the destination
    /// the per-mapper values are cached once in `qbits` (state is frozen
    /// until write-back, so the cache also serves the local Reduce fold
    /// in [`WorkerCore::decode_and_fold`]). Steady state: no allocation.
    pub fn stage_sends(&mut self, job: &Job<'_>, state: &[f64], fabric: &mut dyn Fabric) {
        self.stage_sends_with_extra(job, state, fabric, (0, 0));
    }

    /// [`WorkerCore::stage_sends`] with a pre-staged tally folded into
    /// the `complete_sends` accounting: the cluster worker stages its
    /// dead-peer donor duties ([`stage_dead_sender_transfers`]) through
    /// the same fabric *before* this call, so one flush and one
    /// `SendDone` cover the whole iteration.
    pub fn stage_sends_with_extra(
        &mut self,
        job: &Job<'_>,
        state: &[f64],
        fabric: &mut dyn Fabric,
        extra: (u32, u64),
    ) {
        let (g, alloc, prog) = (job.graph, job.alloc, job.program);
        let me = self.prep.me;
        let (combined, r, sb, src_only) = (self.combined, self.r, self.sb, self.src_only);
        // flight recorder: everything outside the fabric calls is Encode
        // (Map evaluation is fused into the encode loops); time spent
        // inside `stage_*` is Stage and `complete_sends` is the fabric's
        // [`Fabric::flush_phase`] (Flush, or FlushWait when the physical
        // writes run on the transport's writer thread instead). The
        // clock only runs while tracing is on, so untraced runs pay a
        // branch per fabric call and nothing else.
        let traced = self.obs.enabled();
        let t0 = if traced { now_ns() } else { 0 };
        let mut stage_ns = 0u64;
        self.refresh_local_cache(job, state);
        let qbits: &[u64] = &self.qbits;
        let value = move |i: Vertex, j: Vertex| {
            if src_only {
                qbits[j as usize]
            } else {
                iv_value(g, alloc, prog, state, combined, i, j)
            }
        };
        let mut iter_frames = extra.0;
        let mut iter_bytes = extra.1;

        let plan = &self.prep.plan;
        let failed = !self.dead.is_empty();
        for &(l, si) in self.prep.send_plan() {
            let group = plan.group(l as usize);
            if failed && group.servers.iter().any(|s| self.dead.contains(s)) {
                continue; // degraded group: raw donor rows replace the code
            }
            let q = plan.sender_cols(l as usize)[si as usize] as usize;
            let nv = group.total_ivs();
            // when we also decode this group, evaluate into the
            // persistent per-group arena so decode can reuse the values
            // (our skip index is the same on both sides and state is
            // frozen until write-back)
            let vals: &[u64] = match self.prep.recv_groups().binary_search(&l) {
                Ok(slot) => {
                    let range = self.gvals_off[slot]..self.gvals_off[slot] + nv;
                    eval_rows_except(group, si as usize, &value, &mut self.gvals[range.clone()]);
                    &self.gvals[range]
                }
                Err(_) => {
                    eval_rows_except(group, si as usize, &value, &mut self.vals[..nv]);
                    &self.vals[..nv]
                }
            };
            let si = si as usize;
            encode_sender_into(group, si, vals, r, &mut self.cols[..q]);
            let wire = plan.wire_id(l as usize);
            frame::encode_coded(&mut self.sendbuf, me, wire, &self.cols[..q], sb);
            frame::stamp_epoch(&mut self.sendbuf, self.epoch);
            self.receivers.clear();
            for (mi, &m) in group.servers.iter().enumerate() {
                if m != me && group.row_len(mi) > 0 {
                    self.receivers.push(m);
                }
            }
            let ts = if traced { now_ns() } else { 0 };
            fabric.stage_multicast(&self.receivers, &self.sendbuf);
            if traced {
                stage_ns += now_ns() - ts;
            }
            iter_frames += 1; // one multicast = one transmission
            iter_bytes += self.sendbuf.len() as u64;
        }
        for &ti in self.prep.unc_sends() {
            let t = &self.prep.transfers[ti as usize];
            self.ivbits.clear();
            self.ivbits.extend(t.ivs.iter().map(|&(i, j)| value(i, j)));
            frame::encode_uncoded(
                &mut self.sendbuf,
                me,
                self.prep.transfer_ids[ti as usize],
                &self.ivbits,
            );
            frame::stamp_epoch(&mut self.sendbuf, self.epoch);
            // a dead receiver's transfers reroute to its adopter (identity
            // route while everyone is alive)
            let to = self.route[t.receiver as usize];
            let ts = if traced { now_ns() } else { 0 };
            fabric.stage_unicast(to, &self.sendbuf);
            if traced {
                stage_ns += now_ns() - ts;
            }
            if to != me {
                iter_frames += 1;
                iter_bytes += self.sendbuf.len() as u64;
            }
        }
        if failed {
            // donor duties: each degraded group's needed rows ship raw,
            // each from the lowest live member other than the row's owner
            // — every survivor derives the same assignment from its own
            // shard (a `GroupRef` carries all members' rows, and any
            // other member Maps the row's whole batch)
            for l in 0..plan.num_groups() {
                let group = plan.group(l);
                if !group.servers.iter().any(|s| self.dead.contains(s)) {
                    continue;
                }
                let wire = plan.wire_id(l);
                for (mi, &m) in group.servers.iter().enumerate() {
                    if group.row_len(mi) == 0
                        || surviving_donor(group.servers, m, &self.dead) != Some(me)
                    {
                        continue;
                    }
                    self.raw_row.clear();
                    for &(i, j) in group.row(mi) {
                        self.raw_row.push(value(i, j));
                    }
                    frame::encode_recover_row(&mut self.sendbuf, me, wire, m, &self.raw_row);
                    frame::stamp_epoch(&mut self.sendbuf, self.epoch);
                    let to = self.route[m as usize];
                    let ts = if traced { now_ns() } else { 0 };
                    fabric.stage_unicast(to, &self.sendbuf);
                    if traced {
                        stage_ns += now_ns() - ts;
                    }
                    if to != me {
                        iter_frames += 1;
                        iter_bytes += self.sendbuf.len() as u64;
                    }
                }
            }
        }
        let tf = if traced { now_ns() } else { 0 };
        let flush_phase = fabric.flush_phase();
        fabric.complete_sends(iter_frames, iter_bytes);
        if traced {
            let flush_ns = now_ns() - tf;
            // re-lay the interleaved encode/stage work as sequential
            // spans inside the real [t0, tf] window so the per-core
            // timeline stays monotonic and non-overlapping
            let encode_ns = (tf - t0).saturating_sub(stage_ns);
            self.obs.record(Phase::Encode, t0, encode_ns, 0, 0);
            self.obs.record(Phase::Stage, t0 + encode_ns, stage_ns, iter_bytes, iter_frames);
            self.obs.record(flush_phase, tf, flush_ns, 0, 0);
        }
    }

    /// Stash one data frame into its arena slot (state-independent: the
    /// sender already evaluated the bits, we only copy bytes) and count
    /// it toward the current iteration's barrier. Callable from any
    /// receive loop — frames that race ahead of a driver's control
    /// traffic are accepted here and counted toward the next barrier.
    pub fn ingest(&mut self, f: &Frame<'_>) {
        assert!(
            self.try_ingest(f),
            "worker {}: {:?} frame (id {}) for a slot this worker does not receive",
            self.prep.me,
            f.kind,
            f.index
        );
    }

    /// [`WorkerCore::ingest`], but misrouted frames return `false`
    /// instead of panicking — the cluster worker's receive loop offers
    /// each frame to its own core and then to any adopted ghost cores
    /// (id spaces are disjoint across shards, so exactly one core
    /// accepts). Also routes the recovery replacements: a
    /// [`FrameKind::RecoverRow`] lands in the degraded slot's arena
    /// (sender-0 region, unused by coded traffic there), a
    /// [`FrameKind::RecoverPairs`] scatters into the transfer's IV arena
    /// by position.
    pub fn try_ingest(&mut self, f: &Frame<'_>) -> bool {
        match f.kind {
            FrameKind::CodedData => {
                // frame carries the group's canonical wire id (subset
                // rank) — resolve it to our shard-local slot
                let Ok(slot) = self.my_gids.binary_search(&f.index) else {
                    return false;
                };
                let l = self.prep.recv_groups()[slot] as usize;
                let group = self.prep.plan.group(l);
                let m_idx = self.my_row_idx[slot];
                let my_len = group.row_len(m_idx);
                let s_idx = group.member_index(f.sender).expect("sender not in group");
                debug_assert_ne!(s_idx, m_idx, "received own transmission");
                debug_assert!(f.count as usize >= my_len, "short coded frame");
                let base = self.garena_off[slot] + s_idx * my_len;
                for (c, cell) in self.garena[base..base + my_len].iter_mut().enumerate() {
                    *cell = f.col(c, self.sb);
                }
                // duplicates (a straggler's late frame racing its next
                // iteration down the same FIFO connection) overwrite
                // without re-counting — only padding contributions can
                // be in that race, so the bits are immaterial either way
                let seen = &mut self.seen[slot * (self.r + 1) + s_idx];
                if !*seen {
                    *seen = true;
                    self.got_coded += 1;
                }
                true
            }
            FrameKind::RecoverRow => {
                if f.target != self.prep.me {
                    return false;
                }
                let Ok(slot) = self.my_gids.binary_search(&f.index) else {
                    return false;
                };
                debug_assert!(self.degraded[slot], "raw row for a healthy group");
                let l = self.prep.recv_groups()[slot] as usize;
                let m_idx = self.my_row_idx[slot];
                let my_len = self.prep.plan.group(l).row_len(m_idx);
                debug_assert_eq!(f.count as usize, my_len, "raw row length mismatch");
                let base = self.garena_off[slot];
                for (c, cell) in self.garena[base..base + my_len].iter_mut().enumerate() {
                    *cell = f.word(c);
                }
                let seen = &mut self.seen[slot * (self.r + 1) + m_idx];
                if !*seen {
                    *seen = true;
                    self.got_coded += 1;
                }
                true
            }
            FrameKind::UncodedData => {
                // frame carries the transfer's canonical wire id
                // (sender·K + receiver) — resolve to our shard transfer
                let Ok(pos) = self.my_unc_ids.binary_search(&f.index) else {
                    return false;
                };
                let count = f.count as usize;
                debug_assert_eq!(
                    count,
                    self.prep.transfers[self.prep.unc_recv()[pos] as usize].ivs.len()
                );
                let base = self.unc_off[pos];
                for (c, cell) in self.unc_arena[base..base + count].iter_mut().enumerate() {
                    *cell = f.word(c);
                }
                self.got_unc += 1;
                true
            }
            FrameKind::RecoverPairs => {
                if f.target != self.prep.me {
                    return false;
                }
                let Ok(pos) = self.my_unc_ids.binary_search(&f.index) else {
                    return false;
                };
                let base = self.unc_off[pos];
                let end =
                    base + self.prep.transfers[self.prep.unc_recv()[pos] as usize].ivs.len();
                for p in 0..f.count as usize {
                    let (at, bits) = f.update_pair(p);
                    let cell = base + at as usize;
                    assert!(cell < end, "recovery pair out of transfer range");
                    self.unc_arena[cell] = bits;
                }
                self.got_unc += 1;
                true
            }
            _ => unreachable!("ingest on a control frame"),
        }
    }

    /// Has this iteration's expected data all arrived?
    #[inline]
    pub fn data_complete(&self) -> bool {
        self.got_coded == self.expect_coded && self.got_unc == self.expect_unc
    }

    /// Phase 3 (ingest frames): pull data frames from the fabric until
    /// the expected per-iteration counts are met, then reset the tallies
    /// so data racing ahead of the next barrier counts toward it.
    pub fn ingest_all(&mut self, fabric: &mut dyn Fabric) {
        // flight recorder: time blocked inside `recv_data` is RecvWait,
        // the remainder (parse + arena placement) is Ingest
        let traced = self.obs.enabled();
        let t0 = if traced { now_ns() } else { 0 };
        let mut wait_ns = 0u64;
        let mut bytes = 0u64;
        let mut frames = 0u32;
        let mut rbuf = std::mem::take(&mut self.rbuf);
        while !self.data_complete() {
            let tw = if traced { now_ns() } else { 0 };
            assert!(
                fabric.recv_data(&mut rbuf),
                "worker {}: peer disconnected mid-shuffle",
                self.prep.me
            );
            if traced {
                wait_ns += now_ns() - tw;
                bytes += rbuf.len() as u64;
                frames += 1;
            }
            let f = Frame::parse(&rbuf).expect("worker: bad frame");
            self.ingest(&f);
        }
        self.rbuf = rbuf;
        self.reset_ingest();
        if traced {
            let ingest_ns = (now_ns() - t0).saturating_sub(wait_ns);
            self.obs.record(Phase::RecvWait, t0, wait_ns, 0, 0);
            self.obs.record(Phase::Ingest, t0 + wait_ns, ingest_ns, bytes, frames);
        }
    }

    /// Phases 4–6 (decode → fold → finalize): cancel and reassemble the
    /// received coded columns, fold local and received IVs in *exactly*
    /// the canonical order every driver shares (local Map values, then
    /// groups ascending, then transfers ascending), and finalize this
    /// worker's fresh states into [`WorkerCore::next_bits`]. Returns the
    /// recovered-and-ownership-checked IV count (the `validated_ivs`
    /// contribution). `oracle`, when given (the engine's validation
    /// mode), must be the IV value function over the *full* state; every
    /// decoded bit is asserted against it — a receiver over a real
    /// transport lacks the source state by design and passes `None`.
    pub fn decode_and_fold(
        &mut self,
        job: &Job<'_>,
        state: &[f64],
        oracle: Option<&(dyn Fn(Vertex, Vertex) -> u64 + Sync)>,
    ) -> u32 {
        let (g, alloc, prog) = (job.graph, job.alloc, job.program);
        let me = self.prep.me;
        let (r, src_only) = (self.r, self.src_only);
        let plan = &self.prep.plan;
        let reduce_slot: &[u32] = &self.prep.reduce_slot;
        let qbits: &[u64] = &self.qbits;
        let rows = &alloc.reduce_sets[me as usize];
        // flight recorder: the coded cancellation loop is Decode, the
        // rest (local fold, uncoded fold, finalize) is Fold
        let traced = self.obs.enabled();
        let t0 = if traced { now_ns() } else { 0 };

        // local fold; the src_only path reuses the per-iteration `qbits`
        // cache filled at stage time — every neighbor j here has degree
        // ≥ 1 and is mapped by this worker, so its entry is a real value
        for (slot, &i) in rows.iter().enumerate() {
            let mut acc = prog.identity();
            for &j in g.neighbors(i) {
                if alloc.maps(me, j) {
                    let v = if src_only {
                        f64::from_bits(qbits[j as usize])
                    } else {
                        prog.map(i, j, state[j as usize], g)
                    };
                    acc = prog.combine(acc, v);
                }
            }
            self.accs[slot] = acc;
        }

        let mut validated = 0u32;
        let td = if traced { now_ns() } else { 0 };
        // coded: cancel + reassemble per group, fold in pair order. The
        // cancellation values were evaluated into `gvals` at stage time
        // (same skip index, same frozen state); a recv-group we did not
        // send in has every other row empty, so its stale arena entries
        // are never read by the decoder
        for (slot_idx, &gi) in self.prep.recv_groups().iter().enumerate() {
            let group = plan.group(gi as usize);
            let m_idx = self.my_row_idx[slot_idx];
            let my_len = group.row_len(m_idx);
            let nv = group.total_ivs();
            let gvals = &self.gvals[self.gvals_off[slot_idx]..self.gvals_off[slot_idx] + nv];
            let bits = &mut self.bits[..my_len];
            let base = self.garena_off[slot_idx];
            if self.degraded[slot_idx] {
                // degraded group: the donor shipped this row raw — no
                // cancellation, the stored words *are* the IV bits
                bits.copy_from_slice(&self.garena[base..base + my_len]);
            } else {
                bits.fill(0);
                for s_idx in 0..group.members() {
                    if s_idx == m_idx {
                        continue;
                    }
                    decode_sender_into(
                        group,
                        m_idx,
                        s_idx,
                        &self.garena[base + s_idx * my_len..base + (s_idx + 1) * my_len],
                        gvals,
                        r,
                        bits,
                    );
                }
            }
            for (c, &(i, j)) in group.row(m_idx).iter().enumerate() {
                // hard check before touching reduce_slot: the shard only
                // populates slots for this worker's own vertices, so a
                // misrouted IV would otherwise fold silently into the
                // wrong accumulator
                assert_eq!(
                    alloc.reduce_owner[i as usize], me,
                    "decoded IV for a vertex this worker does not reduce"
                );
                if let Some(oracle) = oracle {
                    assert_eq!(bits[c], oracle(i, j), "coded decode mismatch at ({i}, {j})");
                }
                let slot = reduce_slot[i as usize] as usize;
                self.accs[slot] = prog.combine(self.accs[slot], f64::from_bits(bits[c]));
            }
            validated += my_len as u32;
        }
        let decode_ns = if traced { now_ns() - td } else { 0 };
        // uncoded: fold received batches in canonical transfer order
        for (pos, &ti) in self.prep.unc_recv().iter().enumerate() {
            let t = &self.prep.transfers[ti as usize];
            let base = self.unc_off[pos];
            for (c, &(i, _)) in t.ivs.iter().enumerate() {
                assert_eq!(
                    alloc.reduce_owner[i as usize], me,
                    "received IV for a vertex this worker does not reduce"
                );
                let slot = reduce_slot[i as usize] as usize;
                self.accs[slot] =
                    prog.combine(self.accs[slot], f64::from_bits(self.unc_arena[base + c]));
            }
            validated += t.ivs.len() as u32;
        }
        // finalize into the write-back payload (bit-exact states)
        for (slot, &i) in rows.iter().enumerate() {
            self.next_bits[slot] =
                prog.finalize(i, self.accs[slot], state[i as usize], g).to_bits();
        }
        self.last_validated = validated;
        if traced {
            // re-lay as Decode-then-Fold inside the real window (the
            // local fold actually ran first; the track only needs to be
            // monotonic and the durations honest)
            let fold_ns = (now_ns() - t0).saturating_sub(decode_ns);
            self.obs.record(Phase::Decode, t0, decode_ns, 0, self.my_gids.len() as u32);
            self.obs.record(Phase::Fold, t0 + decode_ns, fold_ns, 0, validated);
        }
        validated
    }

    /// Materialize this worker's received IVs — decoded coded rows (the
    /// `gvals` cancellation arena must be fresh from this iteration's
    /// [`WorkerCore::stage_sends`]) plus received uncoded bits — in the
    /// canonical order. Like [`WorkerCore::decode_and_fold`], an
    /// `oracle` (the engine's validation mode) asserts every decoded
    /// bit and the recovered count lands in
    /// [`WorkerCore::last_validated`]. PJRT backend path; allocates.
    #[cfg(feature = "xla")]
    pub fn collect_received(
        &mut self,
        oracle: Option<&(dyn Fn(Vertex, Vertex) -> u64 + Sync)>,
    ) -> Vec<RecoveredIv> {
        let mut out = Vec::new();
        let prep = &self.prep;
        let r = self.r;
        let mut validated = 0u32;
        for (slot_idx, &gi) in prep.recv_groups().iter().enumerate() {
            let group = prep.plan.group(gi as usize);
            let m_idx = self.my_row_idx[slot_idx];
            let my_len = group.row_len(m_idx);
            let nv = group.total_ivs();
            let gvals = &self.gvals[self.gvals_off[slot_idx]..self.gvals_off[slot_idx] + nv];
            let bits = &mut self.bits[..my_len];
            bits.fill(0);
            let base = self.garena_off[slot_idx];
            for s_idx in 0..group.members() {
                if s_idx == m_idx {
                    continue;
                }
                decode_sender_into(
                    group,
                    m_idx,
                    s_idx,
                    &self.garena[base + s_idx * my_len..base + (s_idx + 1) * my_len],
                    gvals,
                    r,
                    bits,
                );
            }
            for (c, &(i, j)) in group.row(m_idx).iter().enumerate() {
                if let Some(oracle) = oracle {
                    assert_eq!(bits[c], oracle(i, j), "coded decode mismatch at ({i}, {j})");
                }
                out.push(RecoveredIv { reducer: i, mapper: j, bits: bits[c] });
            }
            validated += my_len as u32;
        }
        for (pos, &ti) in prep.unc_recv().iter().enumerate() {
            let t = &prep.transfers[ti as usize];
            let base = self.unc_off[pos];
            for (c, &(i, j)) in t.ivs.iter().enumerate() {
                out.push(RecoveredIv { reducer: i, mapper: j, bits: self.unc_arena[base + c] });
            }
            validated += t.ivs.len() as u32;
        }
        self.last_validated = validated;
        out
    }
}

/// Stage the [`FrameKind::RecoverPairs`] replacing a dead worker's
/// uncoded sends: every IV of every transfer the dead worker would have
/// sent is re-evaluated by the lowest surviving replica of its batch,
/// and each donor ships its share as one frame per transfer, addressed
/// to the logical receiver (`target` byte) and routed to that worker's
/// adopter. Every survivor runs this over the same rebuilt shard
/// (`ghost` = `prepare_worker` for the dead id) and stages only its own
/// share, so the pieces are disjoint and complete. Returns the
/// `(frames, bytes)` staged over the wire (self-addressed loopback
/// frames are untallied) for folding into
/// [`WorkerCore::stage_sends_with_extra`].
pub fn stage_dead_sender_transfers(
    job: &Job<'_>,
    ghost: &PreparedWorker,
    dead: &[WorkerId],
    me: WorkerId,
    route: &[WorkerId],
    state: &[f64],
    epoch: u8,
    fabric: &mut dyn Fabric,
) -> (u32, u64) {
    let (g, alloc, prog) = (job.graph, job.alloc, job.program);
    let combined = ghost.scheme.is_combined();
    let mut pairs: Vec<(u32, u64)> = Vec::new();
    let mut buf = Vec::new();
    let (mut frames, mut bytes) = (0u32, 0u64);
    for &ti in ghost.unc_sends() {
        let t = &ghost.transfers[ti as usize];
        pairs.clear();
        for (p, &(i, j)) in t.ivs.iter().enumerate() {
            let b = if combined { j as usize } else { alloc.batch_of(j) };
            if surviving_donor(&alloc.batches[b].servers, t.sender, dead) != Some(me) {
                continue;
            }
            pairs.push((p as u32, iv_value(g, alloc, prog, state, combined, i, j)));
        }
        if pairs.is_empty() {
            continue;
        }
        frame::encode_recover_pairs(
            &mut buf,
            me,
            ghost.transfer_ids[ti as usize],
            t.receiver,
            &pairs,
        );
        frame::stamp_epoch(&mut buf, epoch);
        let to = route[t.receiver as usize];
        fabric.stage_unicast(to, &buf);
        if to != me {
            frames += 1;
            bytes += buf.len() as u64;
        }
    }
    (frames, bytes)
}

// ---------------------------------------------------------------------------
// TransportFabric: the core over a real Transport endpoint
// ---------------------------------------------------------------------------

/// [`Fabric`] over the [`Transport`] buffered surface — what
/// [`run_worker`](super::cluster::run_worker) plugs into the core.
/// Stages ride the batched wire path (one physical write per peer per
/// flush on TCP), `complete_sends` emits the `SendDone` barrier frame
/// carrying the data tally, and `recv_data` filters the leader's
/// `StartReduce` out of the inbound stream (remembered for
/// [`TransportFabric::await_reduce_barrier`]). Keeps the endpoint's
/// lifetime data-send tally for the exit-time counter cross-check.
pub struct TransportFabric<'a> {
    net: &'a dyn Transport,
    me: WorkerId,
    leader: WorkerId,
    ctrl: Vec<u8>,
    saw_start_reduce: bool,
    sent_frames: usize,
    sent_bytes: usize,
    epoch: u8,
    /// Self-addressed staged frames (an adopter acting as its own
    /// ghost's donor): held here instead of crossing the wire, drained
    /// by the worker's receive loop. Untallied everywhere — the
    /// transport counters never see them either, so the model-vs-wire
    /// accounting stays consistent.
    loopback: VecDeque<Vec<u8>>,
}

impl<'a> TransportFabric<'a> {
    pub fn new(net: &'a dyn Transport, me: WorkerId, leader: WorkerId) -> TransportFabric<'a> {
        TransportFabric {
            net,
            me,
            leader,
            ctrl: Vec::new(),
            saw_start_reduce: false,
            sent_frames: 0,
            sent_bytes: 0,
            epoch: 0,
            loopback: VecDeque::new(),
        }
    }

    /// Stamp subsequent `SendDone` barriers with the current recovery
    /// generation so the leader can drop pre-failure stragglers.
    pub fn set_epoch(&mut self, epoch: u8) {
        self.epoch = epoch;
    }

    /// Drain one self-addressed staged frame (see the `loopback` field).
    pub fn pop_loopback(&mut self) -> Option<Vec<u8>> {
        self.loopback.pop_front()
    }

    /// Consume the leader's `StartReduce` barrier: a no-op if
    /// [`Fabric::recv_data`] already swallowed it during the ingest
    /// loop, a blocking receive otherwise. Must be called exactly once
    /// per iteration, after the core's data is complete.
    pub fn await_reduce_barrier(&mut self, rbuf: &mut Vec<u8>) {
        if !self.saw_start_reduce {
            assert!(self.net.recv(self.me, rbuf), "worker {}: peer disconnected", self.me);
            let f = Frame::parse(rbuf).expect("worker: bad frame");
            assert!(
                f.kind == FrameKind::StartReduce,
                "worker {}: unexpected {:?} at the reduce barrier",
                self.me,
                f.kind
            );
        }
        self.saw_start_reduce = false;
    }

    /// On a process-separated transport the endpoint's own counters see
    /// exactly this worker's sends: verify the lifetime tallies against
    /// them before exiting (a shared in-process transport aggregates
    /// every endpoint, so there the *leader* checks the global counter
    /// instead).
    pub fn check_local_stats(&self) {
        if !self.net.stats_are_global() {
            let s = self.net.data_stats();
            assert_eq!(
                (s.data_frames, s.data_bytes),
                (self.sent_frames, self.sent_bytes),
                "worker {}: transport counters disagree with the send tally",
                self.me
            );
        }
    }
}

impl Fabric for TransportFabric<'_> {
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]) {
        self.net.send_multicast_buffered(self.me, receivers, frame);
    }

    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]) {
        if to == self.me {
            self.loopback.push_back(frame.to_vec());
            return;
        }
        self.net.send_unicast_buffered(self.me, to, frame);
    }

    fn complete_sends(&mut self, frames: u32, bytes: u64) {
        // one physical write per peer with staged data (O(peers) syscalls)
        self.net.flush(self.me);
        self.sent_frames += frames as usize;
        self.sent_bytes += bytes as usize;
        frame::encode_send_done(&mut self.ctrl, self.me, u64::from(frames), bytes);
        frame::stamp_epoch(&mut self.ctrl, self.epoch);
        self.net.send_unicast(self.me, self.leader, &self.ctrl);
    }

    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool {
        loop {
            if !self.net.recv(self.me, buf) {
                return false;
            }
            let kind = Frame::parse(buf).expect("worker: bad frame").kind;
            match kind {
                FrameKind::CodedData | FrameKind::UncodedData => return true,
                FrameKind::StartReduce => {
                    assert!(!self.saw_start_reduce, "duplicate StartReduce");
                    self.saw_start_reduce = true;
                }
                other => unreachable!("unexpected {other:?} during shuffle"),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PipelinedFabric: TransportFabric with an asynchronous flush (PR 10)
// ---------------------------------------------------------------------------

/// [`TransportFabric`] with the flush moved off the worker thread: when
/// the transport has an async wire path
/// ([`Transport::flush_begin`](crate::transport::Transport::flush_begin)),
/// `complete_sends` hands the staged per-peer buffers to the
/// transport's writer thread as one *generation* and returns
/// immediately, so iteration *t*'s physical writes overlap *t*'s
/// ingest/decode/fold/write-back and *t + 1*'s encode/stage. The
/// double-buffer discipline (buffers swap against a recycled spare
/// pool; at most `depth` generations in flight) lives in the
/// transport; this fabric adds the protocol-side surface:
///
/// * [`PipelinedFabric::begin_iteration`] /
///   [`PipelinedFabric::commit_iteration`] mark the iteration-open and
///   commit points of the phase machine. Write-back — the only
///   state-mutating step — consumes nothing but fully-ingested local
///   data, so the commit needs no wire barrier; that is *why*
///   bit-identity survives the overlap (pinned against the engine in
///   `tests/driver_matrix.rs`).
/// * [`PipelinedFabric::drain`] blocks until every in-flight
///   generation is on the wire — required before teardown and before
///   the exit-time counter cross-check.
///
/// Everything the leader asserts per iteration (`SendDone` frame/byte
/// tallies, the global data counters) is recorded at *staging* time
/// and therefore stays exact under the overlap; only the transport's
/// `batched_writes` counter lags behind by up to `depth` iterations.
/// Falls back to a synchronous [`Transport::flush`] on transports
/// without an async path (`flush_begin` returns `false`).
pub struct PipelinedFabric<'a> {
    inner: TransportFabric<'a>,
    depth: usize,
    iter_open: bool,
}

impl<'a> PipelinedFabric<'a> {
    /// Wrap a transport endpoint; `depth` = max in-flight flush
    /// generations (clamped to ≥ 1; 1 = classic double buffer).
    pub fn new(
        net: &'a dyn Transport,
        me: WorkerId,
        leader: WorkerId,
        depth: usize,
    ) -> PipelinedFabric<'a> {
        PipelinedFabric {
            inner: TransportFabric::new(net, me, leader),
            depth: depth.max(1),
            iter_open: false,
        }
    }

    /// See [`TransportFabric::set_epoch`].
    pub fn set_epoch(&mut self, epoch: u8) {
        self.inner.set_epoch(epoch);
    }

    /// See [`TransportFabric::pop_loopback`].
    pub fn pop_loopback(&mut self) -> Option<Vec<u8>> {
        self.inner.pop_loopback()
    }

    /// See [`TransportFabric::await_reduce_barrier`].
    pub fn await_reduce_barrier(&mut self, rbuf: &mut Vec<u8>) {
        self.inner.await_reduce_barrier(rbuf);
    }

    /// See [`TransportFabric::check_local_stats`]. The staging-time
    /// counters this compares are exact even with writes in flight,
    /// but call [`PipelinedFabric::drain`] first anyway so teardown
    /// cannot clip a generation mid-write.
    pub fn check_local_stats(&self) {
        self.inner.check_local_stats();
    }

    /// Open iteration *t + 1*'s staging window. Under the overlap this
    /// is purely a marker: backpressure is applied where it belongs, at
    /// the `complete_sends` hand-off, which blocks while `depth`
    /// generations are already in flight. Re-opening without a commit
    /// is legal — a recovery epoch restarts an abandoned attempt.
    pub fn begin_iteration(&mut self) {
        self.iter_open = true;
    }

    /// Commit iteration *t*: write-back has consumed the ingested data.
    /// No wire barrier — iteration *t*'s outbound generation may still
    /// be in flight (the epoch byte on every frame disambiguates
    /// in-flight generations on the receive side).
    pub fn commit_iteration(&mut self) {
        debug_assert!(self.iter_open, "commit_iteration: no open iteration");
        self.iter_open = false;
    }

    /// Block until every in-flight generation is fully written (or the
    /// writer shut down). Call before `leave`/`abort`/`fail_endpoint`
    /// and before [`PipelinedFabric::check_local_stats`].
    pub fn drain(&mut self) {
        self.inner.net.flush_wait(self.inner.me);
    }
}

impl Fabric for PipelinedFabric<'_> {
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]) {
        self.inner.stage_multicast(receivers, frame);
    }

    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]) {
        self.inner.stage_unicast(to, frame);
    }

    fn complete_sends(&mut self, frames: u32, bytes: u64) {
        // hand the staged buffers to the writer thread; sync fallback
        // when the transport has no async path (in-proc rings deliver
        // eagerly, chaos wraps its own delivery discipline)
        if !self.inner.net.flush_begin(self.inner.me, self.depth) {
            self.inner.net.flush(self.inner.me);
        }
        self.inner.sent_frames += frames as usize;
        self.inner.sent_bytes += bytes as usize;
        // SendDone rides the leader connection eagerly — the writer
        // thread owns only the peer data connections — and carries the
        // staging-time tally, so leader accounting stays exact
        frame::encode_send_done(&mut self.inner.ctrl, self.inner.me, u64::from(frames), bytes);
        frame::stamp_epoch(&mut self.inner.ctrl, self.inner.epoch);
        self.inner.net.send_unicast(self.inner.me, self.inner.leader, &self.inner.ctrl);
    }

    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool {
        self.inner.recv_data(buf)
    }

    fn flush_phase(&self) -> Phase {
        Phase::FlushWait
    }
}

// ---------------------------------------------------------------------------
// WireFabric: the cluster worker's fabric choice (--fabric sync|pipelined)
// ---------------------------------------------------------------------------

/// The cluster worker's runtime fabric selection
/// ([`FabricKind`](super::config::FabricKind), `cluster --fabric`):
/// either the synchronous [`TransportFabric`] oracle or the overlapped
/// [`PipelinedFabric`], behind one enum so
/// [`run_worker_with`](super::cluster::run_worker_with) stays a single
/// code path. Both variants are bit-identical by construction; the
/// sync-only helpers (`begin_iteration`/`commit_iteration`/`drain`)
/// are no-ops on [`WireFabric::Sync`].
pub enum WireFabric<'a> {
    Sync(TransportFabric<'a>),
    Pipelined(PipelinedFabric<'a>),
}

impl<'a> WireFabric<'a> {
    /// Build the fabric `kind` selects over one transport endpoint.
    pub fn new(
        net: &'a dyn Transport,
        me: WorkerId,
        leader: WorkerId,
        kind: super::config::FabricKind,
        depth: usize,
    ) -> WireFabric<'a> {
        match kind {
            super::config::FabricKind::Sync => {
                WireFabric::Sync(TransportFabric::new(net, me, leader))
            }
            super::config::FabricKind::Pipelined => {
                WireFabric::Pipelined(PipelinedFabric::new(net, me, leader, depth))
            }
        }
    }

    /// See [`TransportFabric::set_epoch`].
    pub fn set_epoch(&mut self, epoch: u8) {
        match self {
            WireFabric::Sync(f) => f.set_epoch(epoch),
            WireFabric::Pipelined(f) => f.set_epoch(epoch),
        }
    }

    /// See [`TransportFabric::pop_loopback`].
    pub fn pop_loopback(&mut self) -> Option<Vec<u8>> {
        match self {
            WireFabric::Sync(f) => f.pop_loopback(),
            WireFabric::Pipelined(f) => f.pop_loopback(),
        }
    }

    /// See [`TransportFabric::await_reduce_barrier`].
    pub fn await_reduce_barrier(&mut self, rbuf: &mut Vec<u8>) {
        match self {
            WireFabric::Sync(f) => f.await_reduce_barrier(rbuf),
            WireFabric::Pipelined(f) => f.await_reduce_barrier(rbuf),
        }
    }

    /// See [`TransportFabric::check_local_stats`].
    pub fn check_local_stats(&self) {
        match self {
            WireFabric::Sync(f) => f.check_local_stats(),
            WireFabric::Pipelined(f) => f.check_local_stats(),
        }
    }

    /// See [`PipelinedFabric::begin_iteration`] (no-op on sync).
    pub fn begin_iteration(&mut self) {
        if let WireFabric::Pipelined(f) = self {
            f.begin_iteration();
        }
    }

    /// See [`PipelinedFabric::commit_iteration`] (no-op on sync).
    pub fn commit_iteration(&mut self) {
        if let WireFabric::Pipelined(f) = self {
            f.commit_iteration();
        }
    }

    /// See [`PipelinedFabric::drain`] (no-op on sync — every flush
    /// already completed synchronously).
    pub fn drain(&mut self) {
        if let WireFabric::Pipelined(f) = self {
            f.drain();
        }
    }
}

impl Fabric for WireFabric<'_> {
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]) {
        match self {
            WireFabric::Sync(f) => f.stage_multicast(receivers, frame),
            WireFabric::Pipelined(f) => f.stage_multicast(receivers, frame),
        }
    }

    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]) {
        match self {
            WireFabric::Sync(f) => f.stage_unicast(to, frame),
            WireFabric::Pipelined(f) => f.stage_unicast(to, frame),
        }
    }

    fn complete_sends(&mut self, frames: u32, bytes: u64) {
        match self {
            WireFabric::Sync(f) => f.complete_sends(frames, bytes),
            WireFabric::Pipelined(f) => f.complete_sends(frames, bytes),
        }
    }

    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool {
        match self {
            WireFabric::Sync(f) => f.recv_data(buf),
            WireFabric::Pipelined(f) => f.recv_data(buf),
        }
    }

    fn flush_phase(&self) -> Phase {
        match self {
            WireFabric::Sync(f) => f.flush_phase(),
            WireFabric::Pipelined(f) => f.flush_phase(),
        }
    }
}

// ---------------------------------------------------------------------------
// DirectFabric: in-memory frame handoff between the cores of one process
// ---------------------------------------------------------------------------

/// One core's staged output for one iteration: serialized frames plus
/// per-frame receiver lists, all in capacity-retained flat buffers
/// (steady state: no allocation).
#[derive(Default)]
pub struct SendLog {
    bytes: Vec<u8>,
    /// Per frame: `(byte start, byte end, receiver start, receiver end)`.
    frames: Vec<(u32, u32, u32, u32)>,
    recv: Vec<WorkerId>,
    frames_tally: u32,
    bytes_tally: u64,
}

impl SendLog {
    fn clear(&mut self) {
        self.bytes.clear();
        self.frames.clear();
        self.recv.clear();
        self.frames_tally = 0;
        self.bytes_tally = 0;
    }
}

/// In-memory [`Fabric`] between the `K` cores of one process: per-core
/// send logs, phase-synchronous. The driver stages every core (possibly
/// in parallel — each [`DirectSender`] writes only its own log), then
/// lets every core ingest (again in parallel — [`DirectReceiver`]s only
/// read the logs). Ingest order is senders ascending, staging order
/// within a sender: deterministic, and immaterial to results because
/// the core stashes frames position-determined by wire id.
#[derive(Default)]
pub struct DirectFabric {
    logs: Vec<SendLog>,
}

impl DirectFabric {
    /// Reset for a new iteration of `k` cores (buffers retain capacity).
    pub fn begin_iteration(&mut self, k: usize) {
        if self.logs.len() != k {
            self.logs = (0..k).map(|_| SendLog::default()).collect();
        }
        for log in &mut self.logs {
            log.clear();
        }
    }

    /// The per-core send logs, for zipping with the cores in the stage
    /// phase (`logs_mut()[kk]` belongs to core `kk`).
    pub fn logs_mut(&mut self) -> &mut [SendLog] {
        &mut self.logs
    }

    /// Read view of the logs for the ingest phase.
    pub fn logs(&self) -> &[SendLog] {
        &self.logs
    }

    /// Total staged data tally across all cores: `(frames, serialized
    /// bytes)` — the engine asserts it equals the accounting replay's
    /// modeled message count and `wire_bytes_with_headers()`.
    pub fn tally(&self) -> (usize, usize) {
        self.logs
            .iter()
            .fold((0, 0), |(f, b), log| {
                (f + log.frames_tally as usize, b + log.bytes_tally as usize)
            })
    }
}

/// The staging half of the [`DirectFabric`]: one core's endpoint during
/// the stage phase. `recv_data` is unreachable by the [`Fabric`]
/// contract.
pub struct DirectSender<'a> {
    log: &'a mut SendLog,
}

impl<'a> DirectSender<'a> {
    pub fn new(log: &'a mut SendLog) -> DirectSender<'a> {
        DirectSender { log }
    }
}

impl Fabric for DirectSender<'_> {
    fn stage_multicast(&mut self, receivers: &[WorkerId], frame: &[u8]) {
        let (b0, r0) = (self.log.bytes.len() as u32, self.log.recv.len() as u32);
        self.log.bytes.extend_from_slice(frame);
        self.log.recv.extend_from_slice(receivers);
        self.log
            .frames
            .push((b0, self.log.bytes.len() as u32, r0, self.log.recv.len() as u32));
    }

    fn stage_unicast(&mut self, to: WorkerId, frame: &[u8]) {
        self.stage_multicast(std::slice::from_ref(&to), frame);
    }

    fn complete_sends(&mut self, frames: u32, bytes: u64) {
        // `<=`: self-addressed recovery frames are staged but untallied
        debug_assert!(frames as usize <= self.log.frames.len(), "stage/tally drift");
        self.log.frames_tally = frames;
        self.log.bytes_tally = bytes;
    }

    fn recv_data(&mut self, _buf: &mut Vec<u8>) -> bool {
        unreachable!("DirectFabric: the stage phase has no inbound frames")
    }
}

/// The ingest half of the [`DirectFabric`]: a cursor over all cores'
/// logs yielding, in sender-ascending order, exactly the frames
/// addressed to `me`. Staging calls are unreachable by the [`Fabric`]
/// contract.
pub struct DirectReceiver<'a> {
    logs: &'a [SendLog],
    me: WorkerId,
    sender: usize,
    frame: usize,
}

impl<'a> DirectReceiver<'a> {
    pub fn new(logs: &'a [SendLog], me: WorkerId) -> DirectReceiver<'a> {
        DirectReceiver { logs, me, sender: 0, frame: 0 }
    }
}

impl Fabric for DirectReceiver<'_> {
    fn stage_multicast(&mut self, _receivers: &[WorkerId], _frame: &[u8]) {
        unreachable!("DirectFabric: the ingest phase stages nothing")
    }

    fn stage_unicast(&mut self, _to: WorkerId, _frame: &[u8]) {
        unreachable!("DirectFabric: the ingest phase stages nothing")
    }

    fn complete_sends(&mut self, _frames: u32, _bytes: u64) {
        unreachable!("DirectFabric: the ingest phase stages nothing")
    }

    fn recv_data(&mut self, buf: &mut Vec<u8>) -> bool {
        while self.sender < self.logs.len() {
            let log = &self.logs[self.sender];
            while self.frame < log.frames.len() {
                let (b0, b1, r0, r1) = log.frames[self.frame];
                self.frame += 1;
                if log.recv[r0 as usize..r1 as usize].contains(&self.me) {
                    buf.clear();
                    buf.extend_from_slice(&log.bytes[b0 as usize..b1 as usize]);
                    return true;
                }
            }
            self.sender += 1;
            self.frame = 0;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::Scheme;
    use super::super::engine::prepare_worker;
    use super::*;
    use crate::allocation::Allocation;
    use crate::graph::er::er;
    use crate::mapreduce::program::run_single_machine;
    use crate::mapreduce::PageRank;
    use crate::transport::InProcNet;
    use crate::util::rng::DetRng;

    #[test]
    fn direct_fabric_routes_frames_by_receiver_list() {
        let mut fab = DirectFabric::default();
        fab.begin_iteration(3);
        let mut buf = Vec::new();
        {
            let logs = fab.logs_mut();
            let mut s0 = DirectSender::new(&mut logs[0]);
            frame::encode_coded(&mut buf, 0, 7, &[0xAA, 0xBB], 4);
            s0.stage_multicast(&[1, 2], &buf);
            frame::encode_uncoded(&mut buf, 0, 2, &[5]);
            s0.stage_unicast(2, &buf);
            s0.complete_sends(2, 0);
        }
        {
            let logs = fab.logs_mut();
            let mut s1 = DirectSender::new(&mut logs[1]);
            frame::encode_uncoded(&mut buf, 1, 5, &[9, 9]);
            s1.stage_unicast(2, &buf);
            s1.complete_sends(1, 0);
        }
        // receiver 1 sees only the multicast
        let mut rx = DirectReceiver::new(fab.logs(), 1);
        let mut rbuf = Vec::new();
        assert!(rx.recv_data(&mut rbuf));
        let f = Frame::parse(&rbuf).unwrap();
        assert_eq!((f.kind, f.index), (FrameKind::CodedData, 7));
        assert_eq!(f.col(1, 4), 0xBB);
        assert!(!rx.recv_data(&mut rbuf), "nothing else addressed to 1");
        // receiver 2 sees all three, sender-ascending
        let mut rx = DirectReceiver::new(fab.logs(), 2);
        let mut kinds = Vec::new();
        while rx.recv_data(&mut rbuf) {
            kinds.push((Frame::parse(&rbuf).unwrap().sender, Frame::parse(&rbuf).unwrap().kind));
        }
        assert_eq!(
            kinds,
            vec![
                (0, FrameKind::CodedData),
                (0, FrameKind::UncodedData),
                (1, FrameKind::UncodedData)
            ]
        );
        // sender 0 staged nothing for itself
        let mut rx = DirectReceiver::new(fab.logs(), 0);
        assert!(!rx.recv_data(&mut rbuf));
        assert_eq!(fab.tally().0, 3);
    }

    #[test]
    fn transport_fabric_filters_the_reduce_barrier_and_emits_send_done() {
        // endpoints: 0 = worker under test, 1 = peer, 2 = "leader"
        let net = InProcNet::new(&[8, 8, 8]);
        let mut fab = TransportFabric::new(&net, 0, 2);
        let mut buf = Vec::new();
        // peer data + a StartReduce interleaved ahead of it
        frame::encode_control(&mut buf, FrameKind::StartReduce, 2);
        net.send_unicast(2, 0, &buf);
        frame::encode_uncoded(&mut buf, 1, 1, &[42]);
        net.send_unicast(1, 0, &buf);
        let mut rbuf = Vec::new();
        assert!(fab.recv_data(&mut rbuf), "data frame must come through");
        assert_eq!(Frame::parse(&rbuf).unwrap().kind, FrameKind::UncodedData);
        // the barrier was swallowed and remembered: await returns at once
        fab.await_reduce_barrier(&mut rbuf);
        // staged sends flush + SendDone reaches the leader with the tally
        frame::encode_uncoded(&mut buf, 0, 0, &[7]);
        fab.stage_unicast(1, &buf);
        fab.complete_sends(1, buf.len() as u64);
        assert!(net.recv(1, &mut rbuf));
        assert_eq!(Frame::parse(&rbuf).unwrap().kind, FrameKind::UncodedData);
        assert!(net.recv(2, &mut rbuf));
        let f = Frame::parse(&rbuf).unwrap();
        assert_eq!(f.kind, FrameKind::SendDone);
        assert_eq!(f.index, 1);
        assert_eq!(f.word(0), buf.len() as u64);
    }

    /// Drive K cores by hand through one DirectFabric iteration and
    /// check the assembled next state against the single-machine oracle
    /// — the core's phase machine, with no engine driver around it.
    #[test]
    fn cores_over_direct_fabric_match_single_machine() {
        let n = 90;
        let g = er(n, 0.15, &mut DetRng::seed(75));
        let k = 3usize;
        let alloc = Allocation::er_scheme(n, k, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        for scheme in [Scheme::Coded, Scheme::Uncoded, Scheme::CodedCombined] {
            let mut cores: Vec<WorkerCore> = (0..k)
                .map(|kk| WorkerCore::new(&job, prepare_worker(&job, scheme, kk as WorkerId)))
                .collect();
            let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
            let mut fab = DirectFabric::default();
            fab.begin_iteration(k);
            for (core, log) in cores.iter_mut().zip(fab.logs_mut()) {
                core.stage_sends(&job, &state, &mut DirectSender::new(log));
            }
            let mut next = vec![0.0f64; n];
            for core in cores.iter_mut() {
                let mut rx = DirectReceiver::new(fab.logs(), core.me());
                core.ingest_all(&mut rx);
                core.decode_and_fold(&job, &state, None);
            }
            for (kk, core) in cores.iter().enumerate() {
                for (slot, &i) in alloc.reduce_sets[kk].iter().enumerate() {
                    next[i as usize] = f64::from_bits(core.next_bits()[slot]);
                }
            }
            let want = run_single_machine(&prog, &g, 1);
            for (a, b) in next.iter().zip(&want) {
                assert!((a - b).abs() < 1e-14, "{scheme}: {a} vs {b}");
            }
        }
    }

    /// Drive one iteration of `k` cores over a [`DirectFabric`], with
    /// the workers in `dead` killed before the iteration: survivors (and
    /// the adopter's ghost cores) adopt, stage donor duties, and route
    /// inbound frames by hand exactly like the cluster worker loop.
    /// Returns the assembled next state as bits.
    fn drive_one_degraded_iteration(
        job: &Job<'_>,
        scheme: Scheme,
        k: usize,
        dead: &[WorkerId],
    ) -> Vec<u64> {
        let (g, alloc, prog) = (job.graph, job.alloc, job.program);
        let n = g.n();
        let epoch = u8::from(!dead.is_empty());
        let survivors: Vec<WorkerId> = (0..k as WorkerId).filter(|w| !dead.contains(w)).collect();
        let adopter = survivors[0];
        let route: Vec<WorkerId> =
            (0..k as WorkerId).map(|w| if dead.contains(&w) { adopter } else { w }).collect();
        let ghost_preps: Vec<_> =
            dead.iter().map(|&w| prepare_worker(job, scheme, w)).collect();
        let mut ghosts: Vec<WorkerCore> = dead
            .iter()
            .map(|&w| {
                let mut ghost = WorkerCore::new(job, prepare_worker(job, scheme, w));
                ghost.adopt(job, dead, epoch);
                ghost
            })
            .collect();
        let mut cores: Vec<WorkerCore> = survivors
            .iter()
            .map(|&kk| {
                let mut c = WorkerCore::new(job, prepare_worker(job, scheme, kk));
                if !dead.is_empty() {
                    c.adopt(job, dead, epoch);
                }
                c
            })
            .collect();
        let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, g)).collect();
        let mut fab = DirectFabric::default();
        fab.begin_iteration(k);
        for core in cores.iter_mut() {
            let me = core.me();
            let mut sender = DirectSender::new(&mut fab.logs_mut()[me as usize]);
            let mut extra = (0u32, 0u64);
            for ghost_prep in &ghost_preps {
                let (f, b) = stage_dead_sender_transfers(
                    job, ghost_prep, dead, me, &route, &state, epoch, &mut sender,
                );
                extra.0 += f;
                extra.1 += b;
            }
            core.stage_sends_with_extra(job, &state, &mut sender, extra);
        }
        let mut next_bits = vec![0u64; n];
        let mut rbuf = Vec::new();
        for core in cores.iter_mut() {
            let me = core.me();
            let hosts_ghosts = me == adopter;
            let mut rx = DirectReceiver::new(fab.logs(), me);
            while !(core.data_complete()
                && (!hosts_ghosts || ghosts.iter().all(WorkerCore::data_complete)))
            {
                assert!(rx.recv_data(&mut rbuf), "{scheme}: worker {me} starved");
                let f = Frame::parse(&rbuf).unwrap();
                let taken = core.try_ingest(&f)
                    || (hosts_ghosts && ghosts.iter_mut().any(|ghost| ghost.try_ingest(&f)));
                assert!(taken, "{scheme}: unroutable {:?} frame at worker {me}", f.kind);
            }
            core.reset_ingest();
            core.decode_and_fold(job, &state, None);
            for (slot, &i) in alloc.reduce_sets[me as usize].iter().enumerate() {
                next_bits[i as usize] = core.next_bits()[slot];
            }
        }
        for ghost in ghosts.iter_mut() {
            ghost.reset_ingest();
            ghost.refresh_local_cache(job, &state);
            ghost.decode_and_fold(job, &state, None);
            for (slot, &i) in alloc.reduce_sets[ghost.me() as usize].iter().enumerate() {
                next_bits[i as usize] = ghost.next_bits()[slot];
            }
        }
        next_bits
    }

    /// Kill a worker and re-drive the iteration degraded: coded groups
    /// touching the dead worker collapse to raw donor rows, dead-sender
    /// transfers are re-covered by surviving batch replicas, rerouted
    /// frames feed the adopter's ghost core — and the assembled next
    /// state is **bit-identical** to the no-failure run (same IVs,
    /// different senders), on every scheme.
    #[test]
    fn degraded_iteration_is_bit_identical_to_clean_run() {
        let n = 120;
        let g = er(n, 0.12, &mut DetRng::seed(41));
        let k = 4usize;
        let alloc = Allocation::er_scheme(n, k, 2);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        for scheme in
            [Scheme::Coded, Scheme::Uncoded, Scheme::CodedCombined, Scheme::UncodedCombined]
        {
            let clean = drive_one_degraded_iteration(&job, scheme, k, &[]);
            let degraded = drive_one_degraded_iteration(&job, scheme, k, &[1]);
            assert_eq!(clean, degraded, "{scheme}: degraded run diverged");
            // absolute anchor: the clean run tracks the single machine
            let want = run_single_machine(&prog, &g, 1);
            for (a, b) in clean.iter().zip(&want) {
                let a = f64::from_bits(*a);
                assert!((a - b).abs() < 1e-14, "{scheme}: {a} vs {b}");
            }
        }
    }

    /// Two simultaneous failures within `r − 1 = 2` tolerance: both
    /// ghost shards stack on the adopter and the result still matches
    /// the clean run bit for bit.
    #[test]
    fn degraded_iteration_survives_two_failures_within_tolerance() {
        let n = 100;
        let g = er(n, 0.15, &mut DetRng::seed(43));
        let k = 5usize;
        let alloc = Allocation::er_scheme(n, k, 3);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        for scheme in [Scheme::Coded, Scheme::Uncoded] {
            let clean = drive_one_degraded_iteration(&job, scheme, k, &[]);
            let degraded = drive_one_degraded_iteration(&job, scheme, k, &[1, 3]);
            assert_eq!(clean, degraded, "{scheme}: double-failure run diverged");
        }
    }

    /// At `r = 5` the per-value segment count (`ceil(8 / seg_bytes)` =
    /// 4 real segments of 2 bytes) is smaller than `r`, so the
    /// highest-ranked sender of each group carries pure padding for the
    /// lowest member: the cutoff may skip exactly that sender's frame
    /// and no other, and the decode still reconstructs every bit.
    #[test]
    fn straggler_cutoff_skips_only_padding_segments() {
        let n = 60;
        let g = er(n, 0.2, &mut DetRng::seed(77));
        let k = 6usize;
        let alloc = Allocation::er_scheme(n, k, 5);
        let prog = PageRank::default();
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let scheme = Scheme::Coded;
        let mut cores: Vec<WorkerCore> = (0..k)
            .map(|kk| WorkerCore::new(&job, prepare_worker(&job, scheme, kk as WorkerId)))
            .collect();
        let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
        let mut fab = DirectFabric::default();
        fab.begin_iteration(k);
        for (core, log) in cores.iter_mut().zip(fab.logs_mut()) {
            core.stage_sends(&job, &state, &mut DirectSender::new(log));
        }
        // deliver to worker 0 everything except the frames from senders
        // 4 and 5 (5 is pure padding for member 0, 4 is a real segment)
        let core = &mut cores[0];
        let mut rx = DirectReceiver::new(fab.logs(), 0);
        let mut rbuf = Vec::new();
        let mut held = Vec::new();
        while rx.recv_data(&mut rbuf) {
            let f = Frame::parse(&rbuf).unwrap();
            if f.kind == FrameKind::CodedData && f.sender >= 4 {
                held.push(rbuf.clone());
                continue;
            }
            core.ingest(&f);
        }
        assert!(!core.data_complete());
        assert!(!core.try_cutoff(), "a real segment is missing: no cutoff");
        for buf in &held {
            let f = Frame::parse(buf).unwrap();
            if f.sender == 4 {
                core.ingest(&f);
            }
        }
        assert!(core.try_cutoff(), "only padding is missing now");
        assert!(core.data_complete());
        assert_eq!(core.skipped(), core.prep().recv_groups().len() as u32);
        // the cutoff decode is still exact on every recovered bit
        let oracle = |i: Vertex, j: Vertex| prog.map(i, j, state[j as usize], &g).to_bits();
        core.reset_ingest();
        let skipped_would_reset = core.skipped();
        assert_eq!(skipped_would_reset, 0, "reset clears the skip tally");
        core.decode_and_fold(&job, &state, Some(&oracle));
    }
}
