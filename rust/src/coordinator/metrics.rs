//! Phase metrics: the quantities every figure of the paper plots.

use crate::obs::{TraceSpan, WorkerPhaseTimes};
use crate::shuffle::load::ShuffleLoad;

/// Simulated per-phase times of one iteration (paper Fig 2 / Fig 7 bars).
/// Each is the max over workers for parallel phases, bus time for serial
/// (Shuffle / state-update) phases.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    pub map_s: f64,
    pub encode_s: f64,
    pub shuffle_s: f64,
    pub decode_s: f64,
    pub reduce_s: f64,
    pub update_s: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.map_s + self.encode_s + self.shuffle_s + self.decode_s + self.reduce_s + self.update_s
    }

    /// The paper's grouping: Encode counts into Map time, Decode into
    /// Reduce time (§VI footnote 1).
    pub fn paper_buckets(&self) -> (f64, f64, f64) {
        (
            self.map_s + self.encode_s,
            self.shuffle_s,
            self.decode_s + self.reduce_s + self.update_s,
        )
    }
}

/// Everything measured in one iteration.
#[derive(Clone, Debug, Default)]
pub struct IterationMetrics {
    pub times: PhaseTimes,
    /// Real wall-clock of the engine's own compute (all phases).
    pub wall_s: f64,
    /// Shuffle traffic.
    pub shuffle: ShuffleLoad,
    /// State write-back traffic.
    pub update: ShuffleLoad,
    /// Recovered IVs validated bit-exact (when validation is on).
    pub validated_ivs: usize,
}

/// What surviving worker loss cost the job — all zeros for a clean run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Workers declared dead over the whole job.
    pub failures: usize,
    /// Multicast groups plus uncoded transfers whose traffic was
    /// re-planned onto surviving replicas.
    pub recovered_groups: usize,
    /// Wall-clock the leader spent computing and shipping recovery
    /// plans (milliseconds, summed over failures).
    pub recovery_ms: f64,
    /// Actual shuffle wire bytes (including failed attempts and raw
    /// donor rows) over the no-failure model's bytes, minus one.
    /// Exactly `0.0` for a clean run.
    pub load_inflation: f64,
    /// Coded straggler frames skipped by worker deadline cutoffs (pure
    /// padding segments — skipping them never changes any bit).
    pub skipped_frames: usize,
}

/// A whole job (possibly multiple iterations).
#[derive(Clone, Debug, Default)]
pub struct JobReport {
    pub iterations: Vec<IterationMetrics>,
    pub final_state: Vec<f64>,
    /// Degraded-mode accounting (cluster drivers only; the engine never
    /// fails and leaves this at the default).
    pub recovery: RecoveryStats,
    /// The flight recorder's raw span timeline (empty when tracing is
    /// off): every phase span of every core, cluster-wide — the engine
    /// drains its cores directly, the cluster leader assembles the
    /// workers' end-of-job `Stats` frames.
    pub spans: Vec<TraceSpan>,
    /// *Measured* wall-clock phase times per `(worker, core)`, folded
    /// from [`JobReport::spans`] — the real counterpart of the modeled
    /// [`PhaseTimes`] in [`IterationMetrics::times`], making
    /// modeled-vs-measured drift a first-class quantity.
    pub measured: Vec<WorkerPhaseTimes>,
}

impl JobReport {
    /// Mean normalized Shuffle load per iteration.
    pub fn mean_normalized_load(&self, n: usize) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().map(|m| m.shuffle.normalized(n)).sum::<f64>()
            / self.iterations.len() as f64
    }

    /// Total simulated execution time.
    pub fn total_time(&self) -> f64 {
        self.iterations.iter().map(|m| m.times.total()).sum()
    }

    /// Summed phase times across iterations.
    pub fn summed_times(&self) -> PhaseTimes {
        let mut t = PhaseTimes::default();
        for m in &self.iterations {
            t.map_s += m.times.map_s;
            t.encode_s += m.times.encode_s;
            t.shuffle_s += m.times.shuffle_s;
            t.decode_s += m.times.decode_s;
            t.reduce_s += m.times.reduce_s;
            t.update_s += m.times.update_s;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_buckets() {
        let t = PhaseTimes {
            map_s: 1.0,
            encode_s: 0.5,
            shuffle_s: 4.0,
            decode_s: 0.25,
            reduce_s: 0.75,
            update_s: 0.5,
        };
        assert!((t.total() - 7.0).abs() < 1e-12);
        let (m, s, r) = t.paper_buckets();
        assert!((m - 1.5).abs() < 1e-12);
        assert!((s - 4.0).abs() < 1e-12);
        assert!((r - 1.5).abs() < 1e-12);
    }

    #[test]
    fn report_aggregation() {
        let mut rep = JobReport::default();
        for _ in 0..2 {
            let mut m = IterationMetrics::default();
            m.times.shuffle_s = 2.0;
            m.shuffle.add_uncoded(10); // 640 paper-bits
            rep.iterations.push(m);
        }
        assert!((rep.total_time() - 4.0).abs() < 1e-12);
        let l = rep.mean_normalized_load(10);
        assert!((l - 640.0 / (100.0 * 64.0)).abs() < 1e-12);
        assert!((rep.summed_times().shuffle_s - 4.0).abs() < 1e-12);
    }
}
