//! `coded-graph` — CLI for the coded distributed graph-analytics framework.
//!
//! ```text
//! coded-graph fig5      [--n 300] [--p 0.1] [--k 5] [--trials 20] [--seed 2018]
//! coded-graph scenario  --id 1|2|3|4 [--scale S] [--full] [--seed 7]
//!                       [--driver engine|cluster-inproc|cluster-tcp|processes]
//! coded-graph models    [--n 400] [--k 6] [--trials 8]
//! coded-graph run       --graph er|rb|sbm|pl --n N --k K --r R
//!                       [--p P] [--q Q] [--gamma G] [--program pagerank|sssp]
//!                       [--scheme coded|uncoded] [--iters I] [--cluster]
//!                       [--trace PATH]
//! coded-graph cluster   --graph er|rb|sbm|pl --n N --k K --r R
//!                       [--transport inproc|tcp] [--processes] [--no-spawn]
//!                       [--check] [--program ...] [--scheme ...] [--iters I]
//!                       [--fabric sync|pipelined] [--pipeline-depth D]
//!                       [--bind IP[:PORT]] [--advertise IP[:PORT]]
//!                       [--fail-worker ID@ITER[,ID@ITER]] [--phase-deadline-ms MS]
//!                       [--policy lowest|spread] [--checkpoint PATH]
//!                       [--checkpoint-every N] [--trace PATH] [--json PATH]
//! coded-graph cluster   --resume PATH [--transport ...] [--check] [--checkpoint ...]
//! coded-graph worker    --connect ADDR --id K [--timeout-s 60]
//!                       [--bind IP[:PORT]] [--advertise IP[:PORT]]
//!                       [--fail-at ITER] [--phase-deadline-ms MS]
//!                       [--fabric sync|pipelined] [--pipeline-depth D]
//!                       [--resume PATH] [--trace PATH]
//! coded-graph simulate  --graph er|rb|sbm|pl --n N --k K --r R
//!                       [--alloc cyclic|er] [--scheme coded|uncoded] [--iters I]
//!                       [--sim-seed S] [--latency-ns NS] [--bandwidth-mbps M]
//!                       [--straggler-prob P] [--straggler-slowdown X]
//!                       [--straggler-dist bernoulli|lognormal]
//!                       [--time python|rust|zero] [--policy lowest|spread]
//!                       [--fabric sync|pipelined]
//!                       [--fail-worker ID@ITER[,ID@ITER]] [--trace PATH] [--json PATH]
//! coded-graph sim-sweep [--ks 16,32,...,2048] [--rs 2,3] [--trials T] [--p P]
//!                       [--gamma G] [--seed S] [--fail-k K] [--fail-r R]
//!                       [--max-batches B] [--json PATH]
//! coded-graph trace-summary --path TRACE.json
//! coded-graph inspect   --graph er|rb|sbm|pl --n N [--p P] [--q Q] [--gamma G]
//! coded-graph artifacts [--dir artifacts]
//! ```
//!
//! `--trace PATH` (run / scenario / cluster / worker) writes the flight
//! recorder's timeline ([`coded_graph::obs`]) as Chrome trace-event JSON
//! — one pid per worker, one tid per core, phase spans as complete
//! events, recovery epochs as instant events — viewable in
//! `chrome://tracing` / Perfetto and foldable back into the paper's
//! phase buckets with `trace-summary`. `--json PATH` (scenario /
//! cluster) writes a machine-readable report: loads, paper buckets,
//! modeled *and* measured phase times, and recovery stats.
//!
//! Every experiment harness lives in `coded_graph::experiments`; the CLI is
//! a thin printer. `cargo bench` regenerates the paper's figures through
//! the same harnesses.
//!
//! `cluster --transport tcp --processes` runs the cluster as real
//! separate OS processes: the leader binds a rendezvous socket, spawns
//! `K` children of this same binary in `worker` mode, distributes the
//! roster + job spec through the bootstrap protocol
//! (`transport::bootstrap`), and drives the unchanged frame protocol
//! across process boundaries. With `--no-spawn` the leader spawns
//! nothing and instead waits (default 600 s) for `K` hand-started
//! `worker` processes to dial the printed rendezvous address.
//!
//! ## Multi-host surface (`--bind` / `--advertise`)
//!
//! Everything defaults to loopback (`127.0.0.1`, ephemeral ports). For a
//! real multi-host `--no-spawn` deployment, give the leader
//! `--bind 0.0.0.0[:PORT]` (PORT pins the rendezvous socket; data
//! listeners always take ephemeral ports on the same interface) and
//! `--advertise <leader-ip>` so the roster carries a routable address;
//! start each worker with `--connect <leader-ip>:PORT --bind 0.0.0.0
//! --advertise <worker-ip>`. **Caveat: there is no authentication or
//! encryption on the rendezvous or data sockets** — anything that can
//! reach the port can join or disrupt the cluster. Bind non-loopback
//! interfaces only inside a trusted network segment.

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::time::Duration;

use coded_graph::allocation::Allocation;
use coded_graph::analysis::theory;
use coded_graph::combinatorics::choose;
use coded_graph::coordinator::cluster::leader_ring_capacity;
use coded_graph::coordinator::{
    prepare, run_cluster, run_leader_with, run_rust, run_sim, run_worker_with,
    try_run_cluster_on_with, AllocKind, BuiltJob, Checkpoint, CheckpointCfg, ClusterError,
    EngineConfig, FabricKind, FailWorker, GraphKind, GraphSpec, Job, JobReport, JobSpec,
    ProgramSpec, RunOpts, Scheme, SimConfig, SimReport, TimeModel, WorkerOpts,
};
use coded_graph::experiments::{fig5, models, scenarios, sim_sweep};
use coded_graph::graph::properties;
use coded_graph::mapreduce::VertexProgram;
use coded_graph::obs::{self, Phase};
use coded_graph::transport::{bootstrap, TcpEndpoint, TransportKind};
use coded_graph::util::benchkit::Table;
use coded_graph::util::cli::Args;
use coded_graph::util::json::Json;
use coded_graph::{Csr, WorkerId};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("fig5") => cmd_fig5(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("models") => cmd_models(&args),
        Some("run") => cmd_run(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("worker") => cmd_worker(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sim-sweep") => cmd_sim_sweep(&args),
        Some("trace-summary") => cmd_trace_summary(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!("coded-graph — coded computing for distributed graph analytics");
    println!("(reproduction of Prakash, Reisizadeh, Pedarsani, Avestimehr 2018)\n");
    println!("subcommands:");
    println!("  fig5       communication-load trade-off (paper Fig 5)");
    println!("  scenario   EC2 PageRank scenarios 1-4 (paper Fig 2 / Fig 7 + SBM)");
    println!("  models     Theorem 1-4 validation sweeps across graph models");
    println!("  run        run one distributed job (pagerank / sssp)");
    println!("  cluster    run a job on the leader/worker cluster (--transport inproc|tcp,");
    println!("             --processes spawns real worker processes, --check vs the engine)");
    println!("  worker     join a --processes cluster (--connect <rendezvous addr> --id <k>)");
    println!("  simulate   run one job on the deterministic virtual-time sim fabric");
    println!("             (K in the thousands; same-seed runs are byte-identical,");
    println!("             --straggler-prob / --straggler-dist bernoulli|lognormal /");
    println!("             --fail-worker / --policy lowest|spread)");
    println!("  sim-sweep  large-K load sweep vs theory + failure-policy replay on");
    println!("             the sim fabric (paper Fig 5 asymptotics; --json PATH)");
    println!();
    println!("  cluster accepts --fail-worker ID@ITER[,ID@ITER] (inject worker deaths;");
    println!("  the job survives up to r-1 of them, adopters included — losing the");
    println!("  adopter cascades its ghosts onto the next survivor under --policy");
    println!("  lowest|spread) and --phase-deadline-ms MS (declare hung workers dead /");
    println!("  cut off stragglers whose frames are pure padding)");
    println!();
    println!("  cluster --fabric sync|pipelined [--pipeline-depth D] picks the worker");
    println!("  wire fabric: pipelined hands each iteration's flush to a writer");
    println!("  thread so wire time overlaps compute (TCP only; bit-identical to");
    println!("  sync, which stays the oracle); simulate --fabric predicts the win");
    println!();
    println!("  cluster --checkpoint PATH [--checkpoint-every N] persists committed");
    println!("  state every N iterations (and always on an abort past tolerance);");
    println!("  cluster --resume PATH rebuilds the job from the checkpoint, warm-");
    println!("  starts a fresh mesh, and finishes bit-identical to an uninterrupted");
    println!("  run (worker --resume PATH warm-starts external worker processes)");
    println!();
    println!("  cluster/worker accept --bind IP[:PORT] / --advertise IP[:PORT] for");
    println!("  multi-host --no-spawn deployments (loopback default; the sockets");
    println!("  carry no auth — bind non-loopback only on trusted networks)");
    println!();
    println!("  run/scenario/cluster/worker accept --trace PATH (write the flight");
    println!("  recorder's timeline as Chrome trace-event JSON: load it in");
    println!("  chrome://tracing or Perfetto; one pid per worker, one tid per core);");
    println!("  scenario/cluster also accept --json PATH (machine-readable report:");
    println!("  loads, paper buckets, modeled + measured phase times, recovery stats)");
    println!("  trace-summary  print per-phase totals of a --trace file (--path FILE)");
    println!("  inspect    generate a graph and print its statistics");
    println!("  artifacts  list the AOT artifacts and smoke-run one");
}

/// `--trace PATH`: dump the report's flight-recorder spans as a Chrome
/// trace-event file (a no-op message when the run recorded nothing).
fn write_trace_if_asked(args: &Args, report: &JobReport) -> Result<(), String> {
    let Some(path) = args.get("trace") else { return Ok(()) };
    obs::write_chrome_trace(path, &report.spans).map_err(|e| format!("--trace {path}: {e}"))?;
    println!("chrome trace: {} spans -> {path}", report.spans.len());
    Ok(())
}

/// One `measured` entry as JSON (seconds, same field names as the
/// modeled times so report consumers can diff them directly).
fn measured_json(w: &coded_graph::obs::WorkerPhaseTimes) -> Json {
    let t = &w.times;
    Json::obj(vec![
        ("worker", Json::Num(w.worker as f64)),
        ("core", Json::Num(w.core as f64)),
        ("map_s", Json::Num(t.map_s)),
        ("encode_s", Json::Num(t.encode_s)),
        ("shuffle_s", Json::Num(t.shuffle_s)),
        ("decode_s", Json::Num(t.decode_s)),
        ("reduce_s", Json::Num(t.reduce_s)),
        ("update_s", Json::Num(t.update_s)),
    ])
}

/// The machine-readable job report behind `cluster --json PATH`.
fn report_json(report: &JobReport, n: usize, k: usize, r: usize, scheme: Scheme) -> Json {
    let t = report.summed_times();
    let (map, shuffle, reduce) = t.paper_buckets();
    let iters: Vec<Json> = report
        .iterations
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("modeled_total_s", Json::Num(m.times.total())),
                ("wall_s", Json::Num(m.wall_s)),
                ("normalized_load", Json::Num(m.shuffle.normalized(n))),
                ("validated_ivs", Json::Num(m.validated_ivs as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("k", Json::Num(k as f64)),
        ("r", Json::Num(r as f64)),
        ("scheme", Json::Str(scheme.token().into())),
        ("iterations", Json::Arr(iters)),
        (
            "modeled_times_s",
            Json::obj(vec![
                ("map", Json::Num(t.map_s)),
                ("encode", Json::Num(t.encode_s)),
                ("shuffle", Json::Num(t.shuffle_s)),
                ("decode", Json::Num(t.decode_s)),
                ("reduce", Json::Num(t.reduce_s)),
                ("update", Json::Num(t.update_s)),
                ("total", Json::Num(t.total())),
            ]),
        ),
        (
            "paper_buckets_s",
            Json::obj(vec![
                ("map", Json::Num(map)),
                ("shuffle", Json::Num(shuffle)),
                ("reduce", Json::Num(reduce)),
            ]),
        ),
        ("mean_normalized_load", Json::Num(report.mean_normalized_load(n))),
        ("measured", Json::Arr(report.measured.iter().map(measured_json).collect())),
        ("span_count", Json::Num(report.spans.len() as f64)),
        ("recovery", recovery_json(&report.recovery)),
    ])
}

fn recovery_json(rec: &coded_graph::coordinator::RecoveryStats) -> Json {
    Json::obj(vec![
        ("failures", Json::Num(rec.failures as f64)),
        ("recovered_groups", Json::Num(rec.recovered_groups as f64)),
        ("recovery_ms", Json::Num(rec.recovery_ms)),
        ("load_inflation", Json::Num(rec.load_inflation)),
        ("skipped_frames", Json::Num(rec.skipped_frames as f64)),
    ])
}

/// The machine-readable r-sweep behind `scenario --json PATH`.
fn scenario_json(sc: &scenarios::Scenario, driver: &str, rows: &[scenarios::ScenarioRow]) -> Json {
    let jrows: Vec<Json> = rows
        .iter()
        .map(|row| {
            let t = &row.times;
            let (map, shuffle, reduce) = t.paper_buckets();
            Json::obj(vec![
                ("r", Json::Num(row.r as f64)),
                ("scheme", Json::Str(row.scheme.token().into())),
                (
                    "modeled_times_s",
                    Json::obj(vec![
                        ("map", Json::Num(t.map_s)),
                        ("encode", Json::Num(t.encode_s)),
                        ("shuffle", Json::Num(t.shuffle_s)),
                        ("decode", Json::Num(t.decode_s)),
                        ("reduce", Json::Num(t.reduce_s)),
                        ("update", Json::Num(t.update_s)),
                        ("total", Json::Num(row.total_s)),
                    ]),
                ),
                (
                    "paper_buckets_s",
                    Json::obj(vec![
                        ("map", Json::Num(map)),
                        ("shuffle", Json::Num(shuffle)),
                        ("reduce", Json::Num(reduce)),
                    ]),
                ),
                ("normalized_load", Json::Num(row.load)),
                ("wall_s", Json::Num(row.wall_s)),
                ("measured", Json::Arr(row.measured.iter().map(measured_json).collect())),
                ("recovery", recovery_json(&row.recovery)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("scenario", Json::Num(sc.id as f64)),
        ("name", Json::Str(sc.name.into())),
        ("n", Json::Num(sc.n as f64)),
        ("k", Json::Num(sc.k as f64)),
        ("driver", Json::Str(driver.into())),
        ("rows", Json::Arr(jrows)),
    ])
}

/// `--json PATH`: write `json` (pretty enough for diffs: one object).
fn write_json_if_asked(args: &Args, json: &Json) -> Result<(), String> {
    let Some(path) = args.get("json") else { return Ok(()) };
    std::fs::write(path, format!("{json}\n")).map_err(|e| format!("--json {path}: {e}"))?;
    println!("json report -> {path}");
    Ok(())
}

/// `coded-graph trace-summary --path FILE`: fold a `--trace` file back
/// into the paper's phase buckets and print a bar table.
fn cmd_trace_summary(args: &Args) -> Result<(), String> {
    args.check_known(&["path"])?;
    let path = args.get("path").ok_or("trace-summary: --path <trace.json> is required")?;
    let raw = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let json = Json::parse(&raw).map_err(|e| format!("{path}: {e}"))?;
    let s = obs::summarize_chrome(&json)?;
    println!(
        "{path}: {} events over {} workers x {} cores ({} recovery marks)\n",
        s.events,
        s.pids.len(),
        s.tids.len(),
        s.recovery_marks
    );
    let max_ms = s.totals_ms.iter().cloned().fold(0.0f64, f64::max).max(1e-9);
    let mut t = Table::new(&["phase", "total", "spans", ""]);
    for ph in Phase::ALL {
        let (ms, cnt) = (s.totals_ms[ph as usize], s.counts[ph as usize]);
        let bar = "#".repeat(((ms / max_ms) * 40.0).round() as usize);
        t.row(&[ph.name().to_string(), format!("{ms:.3}ms"), cnt.to_string(), bar]);
    }
    t.print();
    let (map, shuffle, reduce) = s.paper_buckets_ms();
    println!(
        "\npaper buckets: map+encode={map:.3}ms shuffle={shuffle:.3}ms reduce+update={reduce:.3}ms (total {:.3}ms)",
        s.total_ms()
    );
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "p", "k", "trials", "seed"])?;
    let params = fig5::Fig5Params {
        n: args.get_or("n", 300usize)?,
        p: args.get_or("p", 0.1f64)?,
        k: args.get_or("k", 5usize)?,
        trials: args.get_or("trials", 20usize)?,
        seed: args.get_or("seed", 2018u64)?,
    };
    println!(
        "Fig 5: ER(n={}, p={}), K={}, {} trials\n",
        params.n, params.p, params.k, params.trials
    );
    let rows = fig5::run(params);
    let mut t = Table::new(&[
        "r", "uncoded", "coded", "lower-bound", "finite-pred", "gain", "ci95",
    ]);
    for row in &rows {
        t.row(&[
            row.r.to_string(),
            format!("{:.5}", row.uncoded.mean),
            format!("{:.5}", row.coded.mean),
            format!("{:.5}", row.lower_bound),
            format!("{:.5}", row.coded_finite_pred),
            format!("{:.2}x", row.gain()),
            format!("{:.5}", row.coded.ci95()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<(), String> {
    args.check_known(&["id", "scale", "full", "seed", "driver", "timeout-s", "trace", "json"])?;
    let id = args.get_or("id", 2usize)?;
    let scale = if args.has("full") { 1 } else { args.get_or("scale", 6usize)? };
    let seed = args.get_or("seed", 7u64)?;
    let sc = scenarios::scenario(id, scale);
    let driver = args.get("driver").unwrap_or("engine");
    println!("Scenario {id}: {} (n={}, K={}, driver={driver})\n", sc.name, sc.n, sc.k);
    let rows = match driver {
        "engine" => scenarios::run_scenario_scaled(&sc, seed, scale),
        "cluster-inproc" => {
            scenarios::run_scenario_cluster_scaled(&sc, seed, scale, TransportKind::InProc)
        }
        "cluster-tcp" => {
            scenarios::run_scenario_cluster_scaled(&sc, seed, scale, TransportKind::Tcp)
        }
        "processes" => {
            let timeout = Duration::from_secs(args.get_or("timeout-s", 120u64)?);
            scenario_rows_processes(&sc, seed, scale, timeout)?
        }
        other => {
            return Err(format!(
                "unknown driver {other:?} (engine|cluster-inproc|cluster-tcp|processes)"
            ))
        }
    };
    print_scenario_rows(&rows);
    write_json_if_asked(args, &scenario_json(&sc, driver, &rows))?;
    if let Some(path) = args.get("trace") {
        // one timeline per file: the sweep's last (highest-r) row
        let spans = &rows.last().expect("sweep has rows").spans;
        obs::write_chrome_trace(path, spans).map_err(|e| format!("--trace {path}: {e}"))?;
        println!("chrome trace (last row, {} spans) -> {path}", spans.len());
    }
    let (best_r, speedup) = scenarios::speedup_over_naive(&rows);
    let naive = rows.iter().find(|r| r.r == 1).unwrap();
    println!(
        "\nbest r = {best_r}: {:.1}% speedup over naive MapReduce (r=1)",
        speedup * 100.0
    );
    let rs = theory::r_star(
        naive.times.map_s + naive.times.encode_s,
        naive.times.shuffle_s,
    );
    println!("Remark 10 heuristic r* = sqrt(T_shuffle/T_map) = {rs:.2}");
    Ok(())
}

/// The scenario r-sweep with every row executed as a real multi-process
/// cluster: one bootstrap + spawn cycle per `r`, same rows as the engine
/// driver (modeled metrics are driver-independent).
fn scenario_rows_processes(
    sc: &scenarios::Scenario,
    seed: u64,
    scale: usize,
    timeout: Duration,
) -> Result<Vec<scenarios::ScenarioRow>, String> {
    let base = scenarios::scaled_testbed(sc, scale);
    // the graph is identical for every r (only allocation and scheme
    // vary with r): generate it once and move it through each round's
    // BuiltJob instead of regenerating per row
    let mut graph = scenarios::job_spec(sc, 1, seed, 1).graph.build();
    let mut rows = Vec::new();
    for r in 1..=sc.r_max.min(sc.k) {
        let spec = scenarios::job_spec(sc, r, seed, 1);
        let cfg = EngineConfig { scheme: spec.scheme, ..base };
        let built = BuiltJob { graph, alloc: spec.build_alloc(), program: spec.program.build() };
        let loopback: SocketAddr = "127.0.0.1:0".parse().unwrap();
        let report = run_processes(&spec, &built, &cfg, timeout, true, loopback, None)?;
        rows.push(scenarios::row_from_report(r, spec.scheme, &report, built.graph.n()));
        graph = built.graph;
    }
    Ok(rows)
}

fn print_scenario_rows(rows: &[scenarios::ScenarioRow]) {
    let mut t = Table::new(&[
        "r", "scheme", "map", "encode", "shuffle", "decode", "reduce", "update", "total", "load",
    ]);
    for row in rows {
        t.row(&[
            row.r.to_string(),
            row.scheme.to_string(),
            format!("{:.2}s", row.times.map_s),
            format!("{:.2}s", row.times.encode_s),
            format!("{:.2}s", row.times.shuffle_s),
            format!("{:.2}s", row.times.decode_s),
            format!("{:.2}s", row.times.reduce_s),
            format!("{:.2}s", row.times.update_s),
            format!("{:.2}s", row.total_s),
            format!("{:.5}", row.load),
        ]);
    }
    t.print();
}

fn cmd_models(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "k", "trials", "seed", "p", "q", "gamma"])?;
    let params = models::SweepParams {
        n: args.get_or("n", 400usize)?,
        k: args.get_or("k", 6usize)?,
        trials: args.get_or("trials", 8usize)?,
        seed: args.get_or("seed", 99u64)?,
        p: args.get_or("p", 0.2f64)?,
        q: args.get_or("q", 0.05f64)?,
        gamma: args.get_or("gamma", 2.5f64)?,
    };
    for model in [models::Model::Er, models::Model::Rb, models::Model::Sbm, models::Model::Pl] {
        println!("\n=== {model} model (Theorems 1-4) ===");
        let mut t = Table::new(&["r", "uncoded", "coded", "gain", "thm-upper", "thm-lower"]);
        for row in models::sweep(model, params) {
            t.row(&[
                row.r.to_string(),
                format!("{:.5}", row.uncoded.mean),
                format!("{:.5}", row.coded.mean),
                format!("{:.2}x", row.gain()),
                format!("{:.5}", row.predicted_upper),
                format!("{:.5}", row.predicted_lower),
            ]);
        }
        t.print();
    }
    Ok(())
}

/// Parse `IP` or `IP:PORT` (a bare IP gets port 0 = ephemeral).
fn parse_host_port(raw: &str) -> Result<SocketAddr, String> {
    if let Ok(a) = raw.parse::<SocketAddr>() {
        return Ok(a);
    }
    raw.parse::<std::net::IpAddr>()
        .map(|ip| SocketAddr::new(ip, 0))
        .map_err(|_| format!("bad address {raw:?} (expected IP or IP:PORT)"))
}

/// The `--bind IP[:PORT]` listener address; loopback-ephemeral default.
fn bind_addr(args: &Args) -> Result<SocketAddr, String> {
    parse_host_port(args.get("bind").unwrap_or("127.0.0.1:0"))
}

/// The address peers should dial for the locally-bound `bound`: an
/// `--advertise IP[:PORT]` override replaces the host (multi-homed or
/// NATed deployments); port 0 (or a bare IP) keeps the bound port.
fn advertised(bound: SocketAddr, advertise: Option<&str>) -> Result<SocketAddr, String> {
    let out = match advertise {
        None => bound,
        Some(raw) => {
            let a = parse_host_port(raw)?;
            let port = if a.port() == 0 { bound.port() } else { a.port() };
            SocketAddr::new(a.ip(), port)
        }
    };
    if out.ip().is_unspecified() {
        return Err(format!(
            "{out} is not dialable: binding a wildcard interface requires \
             --advertise <routable-ip> so peers get a concrete address"
        ));
    }
    Ok(out)
}

/// The graph recipe named by `--graph`/`--n`/`--seed` + family params —
/// one construction path shared with worker processes (which decode the
/// same [`GraphSpec`] from the bootstrap job line), so leader and
/// workers cannot drift.
fn graph_spec(args: &Args) -> Result<GraphSpec, String> {
    let n = args.get_or("n", 1000usize)?;
    let seed = args.get_or("seed", 1u64)?;
    let kind = match args.get("graph").unwrap_or("er") {
        "er" => GraphKind::Er { p: args.get_or("p", 0.1f64)? },
        "rb" => GraphKind::Rb { q: args.get_or("q", 0.05f64)? },
        "sbm" => GraphKind::Sbm { p: args.get_or("p", 0.2f64)?, q: args.get_or("q", 0.05f64)? },
        "pl" => GraphKind::Pl {
            gamma: args.get_or("gamma", 2.3f64)?,
            rho_scale: args.get_or("rho-scale", 1.0f64)?,
        },
        other => return Err(format!("unknown graph model {other:?}")),
    };
    Ok(GraphSpec { kind, n, seed })
}

fn build_graph(args: &Args) -> Result<Csr, String> {
    Ok(graph_spec(args)?.build())
}

fn parse_scheme(args: &Args) -> Result<Scheme, String> {
    args.get("scheme").unwrap_or("coded").parse()
}

fn program_spec(args: &Args) -> Result<ProgramSpec, String> {
    Ok(match args.get("program").unwrap_or("pagerank") {
        "pagerank" => ProgramSpec::PageRank,
        "sssp" => ProgramSpec::Sssp { source: args.get_or("source", 0u32)? },
        "cc" => ProgramSpec::Cc,
        other => return Err(format!("unknown program {other:?}")),
    })
}

fn parse_program(args: &Args) -> Result<Box<dyn VertexProgram>, String> {
    Ok(program_spec(args)?.build())
}

#[allow(clippy::too_many_arguments)]
fn print_job_summary(
    report: &JobReport,
    program: &dyn VertexProgram,
    g: &Csr,
    k: usize,
    r: usize,
    scheme: Scheme,
    iters: usize,
) {
    println!(
        "{} x{} iterations on n={} m={} K={k} r={r} ({scheme})",
        program.name(),
        iters,
        g.n(),
        g.m()
    );
    let t = report.summed_times();
    println!(
        "sim times: map={:.3}s encode={:.3}s shuffle={:.3}s decode={:.3}s reduce={:.3}s update={:.3}s total={:.3}s",
        t.map_s, t.encode_s, t.shuffle_s, t.decode_s, t.reduce_s, t.update_s, t.total()
    );
    println!(
        "mean normalized shuffle load: {:.6}",
        report.mean_normalized_load(g.n())
    );
    if !report.measured.is_empty() {
        println!("measured phase times ({} cores):", report.measured.len());
        for w in &report.measured {
            let t = &w.times;
            println!(
                "  worker {:2} core {:2}: encode={:.4}s shuffle={:.4}s decode={:.4}s reduce={:.4}s update={:.4}s",
                w.worker, w.core, t.encode_s, t.shuffle_s, t.decode_s, t.reduce_s, t.update_s
            );
        }
    }
    let mut top: Vec<(usize, f64)> = report.final_state.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top-5 final states: {:?}", &top[..5.min(top.len())]);
}

fn cmd_run(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "graph", "n", "k", "r", "p", "q", "gamma", "rho-scale", "seed", "program", "scheme", "iters",
        "cluster", "source", "trace",
    ])?;
    let g = build_graph(args)?;
    let k = args.get_or("k", 5usize)?;
    let r = args.get_or("r", 2usize)?;
    let iters = args.get_or("iters", 3usize)?;
    let scheme = parse_scheme(args)?;
    let alloc = Allocation::er_scheme(g.n(), k, r);
    let program = parse_program(args)?;
    let cfg = EngineConfig { scheme, ..Default::default() };
    let job = Job { graph: &g, alloc: &alloc, program: &*program };
    let report = if args.has("cluster") {
        println!("driver: in-process cluster ({k} workers + leader)");
        run_cluster(&job, &cfg, iters)
    } else {
        println!("driver: phase engine");
        run_rust(&job, &cfg, iters)
    };
    print_job_summary(&report, &*program, &g, k, r, scheme, iters);
    write_trace_if_asked(args, &report)?;
    Ok(())
}

/// `--fail-worker ID@ITER[,ID@ITER]`: up to two injected worker deaths.
fn parse_fail_workers(args: &Args) -> Result<[Option<FailWorker>; 2], String> {
    let mut out = [None, None];
    let Some(raw) = args.get("fail-worker") else { return Ok(out) };
    let mut specs = raw.split(',');
    for slot in &mut out {
        match specs.next() {
            Some(s) => *slot = Some(s.parse::<FailWorker>().map_err(|e| format!("--fail-worker: {e}"))?),
            None => break,
        }
    }
    if specs.next().is_some() {
        return Err("--fail-worker: at most two ID@ITER specs are supported".into());
    }
    Ok(out)
}

/// The full [`JobSpec`] named by a `cluster` invocation's arguments.
fn cluster_job_spec(args: &Args) -> Result<JobSpec, String> {
    Ok(JobSpec {
        graph: graph_spec(args)?,
        alloc: AllocKind::Er,
        k: args.get_or("k", 5usize)?,
        r: args.get_or("r", 2usize)?,
        program: program_spec(args)?,
        scheme: parse_scheme(args)?,
        iters: args.get_or("iters", 3usize)?,
    })
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "graph", "n", "k", "r", "p", "q", "gamma", "rho-scale", "seed", "program", "scheme", "iters",
        "transport", "source", "processes", "check", "timeout-s", "no-spawn", "bind", "advertise",
        "fail-worker", "phase-deadline-ms", "policy", "checkpoint", "checkpoint-every", "resume",
        "fabric", "pipeline-depth", "trace", "json",
    ])?;
    // --resume PATH: the checkpoint carries the whole job recipe; any
    // job-shape flags on the command line are ignored in its favor
    let (spec, warm, base_iter) = match args.get("resume") {
        Some(path) => {
            let ck = Checkpoint::read(Path::new(path)).map_err(|e| format!("--resume: {e}"))?;
            if ck.iter >= ck.spec.iters {
                return Err(format!(
                    "--resume {path}: checkpoint already holds all {} committed iterations",
                    ck.spec.iters
                ));
            }
            println!(
                "resuming from {path}: {}/{} iterations committed (epoch {} at capture)",
                ck.iter, ck.spec.iters, ck.epoch
            );
            (ck.spec, Some(ck.state), ck.iter)
        }
        None => (cluster_job_spec(args)?, None, 0),
    };
    let run_iters = spec.iters - base_iter;
    let transport: TransportKind = args.get("transport").unwrap_or("inproc").parse()?;
    let processes = args.has("processes") || args.has("no-spawn");
    if processes && transport != TransportKind::Tcp {
        return Err("--processes requires --transport tcp".into());
    }
    let mut cfg = EngineConfig { scheme: spec.scheme, ..Default::default() };
    cfg.fail_workers = parse_fail_workers(args)?;
    cfg.phase_deadline_ms = args
        .get("phase-deadline-ms")
        .map(|v| v.parse::<u64>().map_err(|_| format!("--phase-deadline-ms: cannot parse {v:?}")))
        .transpose()?;
    cfg.policy = args.get("policy").unwrap_or("lowest").parse()?;
    cfg.fabric = args.get("fabric").unwrap_or("sync").parse()?;
    cfg.pipeline_depth = args.get_or("pipeline-depth", 1usize)?;
    if cfg.pipeline_depth == 0 {
        return Err("--pipeline-depth must be >= 1".into());
    }
    let checkpoint = match args.get("checkpoint") {
        Some(path) => Some(CheckpointCfg {
            path: PathBuf::from(path),
            every: args.get_or("checkpoint-every", 1usize)?,
            spec,
            base_iter,
        }),
        None => {
            if args.get("checkpoint-every").is_some() {
                return Err("--checkpoint-every requires --checkpoint PATH".into());
            }
            None
        }
    };
    let opts = RunOpts { warm, checkpoint };
    let built = spec.materialize();
    let (k, r) = (spec.k, spec.r);
    for fw in cfg.fail_workers.iter().flatten() {
        if fw.worker as usize >= k {
            return Err(format!("--fail-worker {fw}: worker id out of range (K={k})"));
        }
    }

    let report = if processes {
        let spawn = !args.has("no-spawn");
        let default_timeout = if spawn { 60 } else { 600 };
        let timeout = Duration::from_secs(args.get_or("timeout-s", default_timeout)?);
        if spawn {
            println!("driver: process-separated cluster over tcp ({k} worker processes + leader)");
        } else {
            println!(
                "driver: process-separated cluster over tcp; waiting for {k} external workers"
            );
        }
        run_processes(
            &spec,
            &built,
            &cfg,
            run_iters,
            &opts,
            args.get("resume"),
            timeout,
            spawn,
            bind_addr(args)?,
            args.get("advertise"),
        )?
    } else {
        println!("driver: cluster over {transport} ({k} workers + leader)");
        try_run_cluster_on_with(&built.job(), &cfg, run_iters, transport, &opts)
            .map_err(|e| format!("cluster run aborted: {e}"))?
    };

    print_job_summary(&report, &*built.program, &built.graph, k, r, spec.scheme, run_iters);
    let wall: f64 = report.iterations.iter().map(|m| m.wall_s).sum();
    println!("real wall time across iterations: {wall:.3}s");
    write_trace_if_asked(args, &report)?;
    write_json_if_asked(args, &report_json(&report, built.graph.n(), k, r, spec.scheme))?;
    if args.has("check") {
        let want = run_rust(&built.job(), &cfg, spec.iters);
        for (i, (a, b)) in report.final_state.iter().zip(&want.final_state).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("--check: state {i} diverges from the engine: {a} vs {b}"));
            }
        }
        println!("--check: final states bit-identical to engine::run_rust");
    }
    Ok(())
}

/// Spawned worker processes, killed on drop so no child outlives a
/// failed leader.
struct Children(Vec<std::process::Child>);

impl Children {
    fn kill_all(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.0.clear();
    }

    /// Collect every child's exit status (they exit on their own after
    /// the leader's Stop); whoever is still running past the deadline is
    /// killed and reported.
    fn reap(&mut self, timeout: Duration) -> Result<(), String> {
        let deadline = std::time::Instant::now() + timeout;
        let mut failed = Vec::new();
        for (i, c) in self.0.iter_mut().enumerate() {
            loop {
                match c.try_wait() {
                    Ok(Some(st)) if st.success() => break,
                    Ok(Some(st)) => {
                        failed.push(format!("worker {i} exited with {st}"));
                        break;
                    }
                    Ok(None) if std::time::Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Ok(None) => {
                        let _ = c.kill();
                        let _ = c.wait();
                        failed.push(format!("worker {i} did not exit in time; killed"));
                        break;
                    }
                    Err(e) => {
                        failed.push(format!("worker {i} wait failed: {e}"));
                        break;
                    }
                }
            }
        }
        self.0.clear();
        if failed.is_empty() {
            Ok(())
        } else {
            Err(failed.join("; "))
        }
    }
}

impl Drop for Children {
    fn drop(&mut self) {
        self.kill_all();
    }
}

/// Run one job as a process-separated cluster: bind the rendezvous +
/// leader data listeners (on `bind`'s interface; its port, if any, pins
/// the rendezvous socket), spawn `K` children of this binary in `worker`
/// mode, bootstrap the roster, wire the leader's own [`TcpEndpoint`],
/// and drive the unchanged frame protocol across process boundaries.
/// `iters` is how many iterations *this* run executes (fewer than
/// `spec.iters` on a resume); `resume` is forwarded to spawned children
/// so their entitled state warm-starts off the same checkpoint file
/// (`--no-spawn` workers must be given `--resume` by hand).
/// `advertise` rewrites the announced addresses for multi-host
/// `--no-spawn` use (see the module docs for the no-auth caveat). A
/// leader-side panic (worker death, protocol violation) tears the mesh
/// down, kills the remaining children, and surfaces as an error.
#[allow(clippy::too_many_arguments)]
fn run_processes(
    spec: &JobSpec,
    built: &BuiltJob,
    cfg: &EngineConfig,
    iters: usize,
    opts: &RunOpts,
    resume: Option<&str>,
    timeout: Duration,
    spawn: bool,
    bind: SocketAddr,
    advertise: Option<&str>,
) -> Result<JobReport, String> {
    let job = built.job();
    let prep = prepare(&job, cfg.scheme);

    let rendezvous = TcpListener::bind(bind).map_err(|e| e.to_string())?;
    let rv_addr = advertised(
        rendezvous.local_addr().map_err(|e| e.to_string())?,
        advertise,
    )?;
    // data listeners always take an ephemeral port on the bind interface
    let data_listener =
        TcpListener::bind(SocketAddr::new(bind.ip(), 0)).map_err(|e| e.to_string())?;
    let leader_bound = data_listener.local_addr().map_err(|e| e.to_string())?;
    // an --advertise port override only applies to the rendezvous socket
    let leader_addr = SocketAddr::new(rv_addr.ip(), leader_bound.port());
    println!("rendezvous: {rv_addr}");

    let mut children = Children(Vec::with_capacity(spec.k));
    if spawn {
        let exe = std::env::current_exe().map_err(|e| e.to_string())?;
        for kk in 0..spec.k {
            let mut cmd = std::process::Command::new(&exe);
            cmd.args(["worker", "--connect", &rv_addr.to_string(), "--id", &kk.to_string()])
                .args(["--timeout-s", &timeout.as_secs().to_string()]);
            // forward fault injection / straggler flags to the child they
            // apply to, so the recovery path runs across real processes
            if let Some(fw) =
                cfg.fail_workers.iter().flatten().find(|fw| fw.worker as usize == kk)
            {
                cmd.args(["--fail-at", &fw.at_iter.to_string()]);
            }
            if let Some(ms) = cfg.phase_deadline_ms {
                cmd.args(["--phase-deadline-ms", &ms.to_string()]);
            }
            // the fabric is a per-worker choice: forward it so spawned
            // processes run the same wire path the leader was asked for
            if cfg.fabric != FabricKind::Sync {
                cmd.args(["--fabric", cfg.fabric.token()]);
                cmd.args(["--pipeline-depth", &cfg.pipeline_depth.to_string()]);
            }
            if let Some(path) = resume {
                cmd.args(["--resume", path]);
            }
            let child = cmd.spawn().map_err(|e| format!("spawn worker {kk}: {e}"))?;
            children.0.push(child);
        }
    }

    let roster = bootstrap::lead(&rendezvous, spec.k, leader_addr, &spec.encode_line(), timeout)
        .map_err(|e| e.to_string())?;
    let cap = leader_ring_capacity(spec.k);
    let net = TcpEndpoint::wire(spec.k as WorkerId, &data_listener, &roster, cap, timeout)
        .map_err(|e| e.to_string())?;

    let report = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_leader_with(&job, cfg, iters, &prep, &net, opts)
    }))
    .map_err(|p| {
        if let Some(err) = p.downcast_ref::<ClusterError>() {
            return format!("cluster run aborted: {err}");
        }
        let msg = p
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| p.downcast_ref::<&str>().copied())
            .unwrap_or("panic");
        format!("cluster run aborted: {msg}")
    })?;
    // clean end: run_leader's guard already half-closed our endpoint, so
    // the workers drain their Stop frames and exit on their own
    children.reap(timeout)?;
    Ok(report)
}

fn cmd_worker(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "connect", "id", "timeout-s", "bind", "advertise", "fail-at", "phase-deadline-ms",
        "fabric", "pipeline-depth", "resume", "trace",
    ])?;
    let rendezvous = args
        .get("connect")
        .ok_or("worker: --connect <rendezvous addr> is required")?
        .parse()
        .map_err(|e| format!("--connect: {e}"))?;
    let id: WorkerId = args
        .get("id")
        .ok_or("worker: --id <k> is required")?
        .parse()
        .map_err(|_| "--id: expected a worker index".to_string())?;
    let timeout = Duration::from_secs(args.get_or("timeout-s", 60u64)?);

    let data_listener = TcpListener::bind(bind_addr(args)?).map_err(|e| e.to_string())?;
    let data_addr = advertised(
        data_listener.local_addr().map_err(|e| e.to_string())?,
        args.get("advertise"),
    )?;
    let (roster, job_line) =
        bootstrap::join(rendezvous, id, data_addr, timeout).map_err(|e| e.to_string())?;
    let spec = JobSpec::decode_line(&job_line)?;
    if spec.k + 1 != roster.len() {
        return Err(format!("job spec K={} does not match roster size {}", spec.k, roster.len()));
    }

    // rebuild the job deterministically from the spec (bit-identical to
    // the leader's), prepare only this worker's shard of it, and wire
    // our endpoint into the mesh — startup and memory scale with the
    // shard (≈ (r+1)/K of the plan), not the whole graph's plan
    let built = spec.materialize();
    let job = built.job();
    let prep = spec.prepare_worker(&built, id);
    let cap = prep.ring_capacity();
    let net = TcpEndpoint::wire(id, &data_listener, &roster, cap, timeout)
        .map_err(|e| e.to_string())?;
    // --resume: warm-start this worker's entitled slice off the same
    // checkpoint file the resuming leader read (the leader replays the
    // remaining iterations; the worker only needs the committed state)
    let warm = match args.get("resume") {
        Some(path) => {
            let ck = Checkpoint::read(Path::new(path)).map_err(|e| format!("--resume: {e}"))?;
            if ck.spec != spec {
                return Err(format!(
                    "--resume {path}: checkpoint describes a different job than the rendezvous spec"
                ));
            }
            Some(ck.state)
        }
        None => None,
    };
    let opts = WorkerOpts {
        fail_at: args
            .get("fail-at")
            .map(|v| v.parse::<usize>().map_err(|_| format!("--fail-at: cannot parse {v:?}")))
            .transpose()?,
        phase_deadline: args
            .get("phase-deadline-ms")
            .map(|v| {
                v.parse::<u64>()
                    .map(Duration::from_millis)
                    .map_err(|_| format!("--phase-deadline-ms: cannot parse {v:?}"))
            })
            .transpose()?,
        trace: true,
        warm,
        fabric: args.get("fabric").unwrap_or("sync").parse()?,
        pipeline_depth: args.get_or("pipeline-depth", 1usize)?,
    };
    // a peer failure panics out of run_worker_with; the guard inside
    // aborts our endpoint and the nonzero exit is the leader's signal
    // (an injected --fail-at death still exits 0: the *endpoint* dies
    // abnormally, the process is reaped cleanly)
    let spans = run_worker_with(id, &job, prep, &net, opts);
    // the leader gets the same spans via the Stats frames; --trace here
    // additionally keeps a local per-process timeline
    if let Some(path) = args.get("trace") {
        obs::write_chrome_trace(path, &spans).map_err(|e| format!("--trace {path}: {e}"))?;
    }
    Ok(())
}

/// The machine-readable sim report behind `simulate --json PATH`. Every
/// value is virtual-time-derived, so same-seed runs write byte-identical
/// files (the acceptance check behind `make sim-smoke`).
fn sim_report_json(rep: &SimReport, n: usize, k: usize, r: usize, scheme: Scheme, cfg: &SimConfig) -> Json {
    let iters: Vec<Json> = rep
        .iterations
        .iter()
        .map(|it| {
            Json::obj(vec![
                ("start_ns", Json::Num(it.start_ns as f64)),
                ("makespan_ns", Json::Num(it.makespan_ns as f64)),
                ("wire_frames", Json::Num(it.wire_frames as f64)),
                ("wire_bytes", Json::Num(it.wire_bytes as f64)),
                ("epoch", Json::Num(it.epoch as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("kind", Json::Str("simulate".into())),
        ("n", Json::Num(n as f64)),
        ("k", Json::Num(k as f64)),
        ("r", Json::Num(r as f64)),
        ("scheme", Json::Str(scheme.token().into())),
        ("policy", Json::Str(cfg.policy.token().into())),
        (
            "fabric",
            Json::Str(if cfg.pipelined { "pipelined" } else { "sync" }.into()),
        ),
        ("sim_seed", Json::Num(cfg.seed as f64)),
        ("latency_ns", Json::Num(cfg.latency_ns as f64)),
        ("bandwidth_bps", Json::Num(cfg.bandwidth_bps)),
        ("straggler_prob", Json::Num(cfg.straggler_prob)),
        ("straggler_dist", Json::Str(cfg.straggler_dist.token().into())),
        ("total_ns", Json::Num(rep.total_ns as f64)),
        ("total_virtual_s", Json::Num(rep.total_virtual_s())),
        ("state_digest", Json::Str(format!("{:016x}", rep.state_digest()))),
        ("clean_normalized_load", Json::Num(rep.clean_load.normalized(n))),
        ("iterations", Json::Arr(iters)),
        ("recovery", recovery_json(&rep.recovery)),
        ("span_count", Json::Num(rep.spans.len() as f64)),
    ])
}

/// `coded-graph simulate`: one job on the virtual-time fabric
/// ([`coded_graph::coordinator::sim`]) — the path that reaches `K` in
/// the thousands, deterministically, on one machine.
fn cmd_simulate(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "graph", "n", "k", "r", "p", "q", "gamma", "rho-scale", "seed", "program", "scheme",
        "iters", "alloc", "source", "sim-seed", "latency-ns", "bandwidth-mbps", "straggler-prob",
        "straggler-slowdown", "straggler-dist", "time", "policy", "fail-worker", "fabric",
        "trace", "json",
    ])?;
    let g = build_graph(args)?;
    let k = args.get_or("k", 16usize)?;
    let r = args.get_or("r", 2usize)?;
    let iters = args.get_or("iters", 3usize)?;
    let scheme = parse_scheme(args)?;
    // cyclic is the default: K batches, so per-worker planning stays
    // feasible at K in the thousands; er is the paper's C(K,r) design
    let alloc = match args.get("alloc").unwrap_or("cyclic") {
        "cyclic" => Allocation::cyclic_scheme(g.n(), k, r),
        "er" => {
            if choose(k, r) > 5_000_000 {
                return Err(format!(
                    "--alloc er at K={k} r={r} needs C(K,r) = {} batches; use --alloc cyclic",
                    choose(k, r)
                ));
            }
            Allocation::er_scheme(g.n(), k, r)
        }
        other => return Err(format!("unknown allocation {other:?} (cyclic|er)")),
    };
    let program = parse_program(args)?;
    let time = match args.get("time").unwrap_or("python") {
        "python" => TimeModel::python_speed(),
        "rust" => TimeModel::rust_speed(),
        "zero" => TimeModel::zero(),
        other => return Err(format!("unknown time model {other:?} (python|rust|zero)")),
    };
    let fail_workers = parse_fail_workers(args)?;
    for fw in fail_workers.iter().flatten() {
        if fw.worker as usize >= k {
            return Err(format!("--fail-worker {fw}: worker id out of range (K={k})"));
        }
    }
    if fail_workers.iter().flatten().count() >= r.max(1) {
        return Err(format!(
            "--fail-worker: at most r-1 = {} deaths are recoverable",
            r.saturating_sub(1)
        ));
    }
    let cfg = SimConfig {
        seed: args.get_or("sim-seed", 2018u64)?,
        latency_ns: args.get_or("latency-ns", 500_000u64)?,
        bandwidth_bps: args.get_or("bandwidth-mbps", 100.0f64)? * 1e6,
        straggler_prob: args.get_or("straggler-prob", 0.0f64)?,
        straggler_slowdown: args.get_or("straggler-slowdown", 4.0f64)?,
        straggler_dist: args.get("straggler-dist").unwrap_or("bernoulli").parse()?,
        time,
        fail_workers,
        policy: args.get("policy").unwrap_or("lowest").parse()?,
        pipelined: args.get("fabric").unwrap_or("sync").parse::<FabricKind>()?
            == FabricKind::Pipelined,
    };
    println!(
        "sim fabric: {} x{iters} iterations on n={} m={} K={k} r={r} ({scheme}, policy={})",
        program.name(),
        g.n(),
        g.m(),
        cfg.policy
    );
    let job = Job { graph: &g, alloc: &alloc, program: &*program };
    let rep = run_sim(&job, scheme, iters, &cfg);
    let mut t = Table::new(&["iter", "epoch", "start", "makespan", "frames", "bytes"]);
    for (i, it) in rep.iterations.iter().enumerate() {
        t.row(&[
            i.to_string(),
            it.epoch.to_string(),
            format!("{:.3}ms", it.start_ns as f64 / 1e6),
            format!("{:.3}ms", it.makespan_ns as f64 / 1e6),
            it.wire_frames.to_string(),
            it.wire_bytes.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nvirtual makespan: {:.4}s; clean normalized load {:.6}; state digest {:016x}",
        rep.total_virtual_s(),
        rep.clean_load.normalized(g.n()),
        rep.state_digest()
    );
    if rep.recovery.failures > 0 {
        println!(
            "recovery: {} failures, {} groups re-planned, load inflation {:.2}%",
            rep.recovery.failures,
            rep.recovery.recovered_groups,
            rep.recovery.load_inflation * 100.0
        );
    }
    if let Some(path) = args.get("trace") {
        obs::write_chrome_trace(path, &rep.spans).map_err(|e| format!("--trace {path}: {e}"))?;
        println!("chrome trace (virtual time): {} spans -> {path}", rep.spans.len());
    }
    write_json_if_asked(args, &sim_report_json(&rep, g.n(), k, r, scheme, &cfg))?;
    Ok(())
}

/// Parse `--NAME a,b,c` into a usize list (default when absent).
fn parse_usize_list(args: &Args, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
    match args.get(name) {
        None => Ok(default.to_vec()),
        Some(raw) => raw
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("--{name}: cannot parse {s:?}"))
            })
            .collect(),
    }
}

/// `coded-graph sim-sweep`: the Fig-5-style large-`K` sweep plus the
/// failure-policy replay ([`sim_sweep`]); `--json` writes
/// `BENCH_sim_sweep.json` (byte-identical across same-seed runs).
fn cmd_sim_sweep(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "ks", "rs", "n-factor", "n-min", "n-max", "p", "gamma", "trials", "seed", "max-batches",
        "fail-k", "fail-r", "sim-iters", "json",
    ])?;
    let d = sim_sweep::SimSweepParams::default();
    let params = sim_sweep::SimSweepParams {
        ks: parse_usize_list(args, "ks", &d.ks)?,
        rs: parse_usize_list(args, "rs", &d.rs)?,
        n_factor: args.get_or("n-factor", d.n_factor)?,
        n_min: args.get_or("n-min", d.n_min)?,
        n_max: args.get_or("n-max", d.n_max)?,
        p: args.get_or("p", d.p)?,
        gamma: args.get_or("gamma", d.gamma)?,
        trials: args.get_or("trials", d.trials)?,
        seed: args.get_or("seed", d.seed)?,
        max_batches: args.get_or("max-batches", d.max_batches)?,
        fail_k: args.get_or("fail-k", d.fail_k)?,
        fail_r: args.get_or("fail-r", d.fail_r)?,
        sim_iters: args.get_or("sim-iters", d.sim_iters)?,
    };
    println!(
        "sim sweep: K in {:?}, r in {:?}, p={}, gamma={}, {} trials/point\n",
        params.ks, params.rs, params.p, params.gamma, params.trials
    );
    let rep = sim_sweep::run(&params);
    let mut t = Table::new(&[
        "model", "K", "r", "n", "uncoded", "coded", "gain", "finite-pred", "asym-pred",
    ]);
    for row in &rep.rows {
        t.row(&[
            row.model.to_string(),
            row.k.to_string(),
            row.r.to_string(),
            row.n.to_string(),
            format!("{:.6}", row.uncoded.mean),
            format!("{:.6}", row.coded.mean),
            format!("{:.2}x", row.gain()),
            format!("{:.6}", row.coded_finite_pred),
            format!("{:.6}", row.coded_asym_pred),
        ]);
    }
    t.print();
    println!("\nfailure-policy replay at K={} (cyclic, r={}):", params.fail_k, params.fail_r);
    let mut t = Table::new(&[
        "policy", "f", "makespan", "clean", "inflation", "load-infl", "groups", "state",
    ]);
    for p in &rep.policies {
        t.row(&[
            p.policy.to_string(),
            p.failures.to_string(),
            format!("{:.4}s", p.total_ns as f64 / 1e9),
            format!("{:.4}s", p.clean_total_ns as f64 / 1e9),
            format!("{:.2}%", p.makespan_inflation() * 100.0),
            format!("{:.2}%", p.load_inflation * 100.0),
            p.recovered_groups.to_string(),
            if p.state_matches_clean { "bit-exact" } else { "DIVERGED" }.to_string(),
        ]);
    }
    t.print();
    write_json_if_asked(args, &rep.to_json(&params))?;
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    args.check_known(&["graph", "n", "p", "q", "gamma", "rho-scale", "seed"])?;
    let g = build_graph(args)?;
    let s = properties::stats(&g);
    println!("n={} m={} density={:.5}", s.n, s.m, s.density);
    println!(
        "degree: min={} mean={:.2} max={} isolated={:.2}%",
        s.min_degree,
        s.mean_degree,
        s.max_degree,
        s.isolated_frac * 100.0
    );
    if let Some(gamma) = properties::powerlaw_exponent_mle(&g, 3) {
        println!("power-law exponent (MLE, d>=3): {gamma:.2}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(args: &Args) -> Result<(), String> {
    args.check_known(&["dir"])?;
    Err("this binary was built without the `xla` feature; rebuild with \
         `--features xla` (and a vendored xla crate) to load PJRT artifacts"
        .into())
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<(), String> {
    args.check_known(&["dir"])?;
    let dir = std::path::PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let rt = coded_graph::runtime::PjrtRuntime::load(&dir).map_err(|e| e.to_string())?;
    println!("artifacts in {}:", dir.display());
    for e in &rt.manifest().entries {
        let shapes: Vec<String> = e.inputs.iter().map(|(s, _)| format!("{s:?}")).collect();
        println!("  {:28} inputs: {}", e.name, shapes.join(" x "));
    }
    // smoke-run the largest pagerank block
    if let Some((entry, b)) = rt.manifest().best_block("pagerank_block") {
        let name = entry.name.clone();
        let a = vec![1.0f32 / b as f32; b * b];
        let x = vec![1.0f32; b];
        let y = rt
            .execute_f32(&name, &[
                coded_graph::runtime::client::Arg::F32(&a),
                coded_graph::runtime::client::Arg::F32(&x),
            ])
            .map_err(|e| e.to_string())?;
        println!("\nsmoke: {name}(uniform) -> y[0] = {} (want 1.0)", y[0]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_port_parsing() {
        assert_eq!(parse_host_port("127.0.0.1:9000").unwrap().port(), 9000);
        let bare = parse_host_port("10.1.2.3").unwrap();
        assert_eq!((bare.ip().to_string().as_str(), bare.port()), ("10.1.2.3", 0));
        assert!(parse_host_port("not-an-ip").is_err());
        assert!(parse_host_port("example.com:80").is_err(), "hostnames are not resolved");
    }

    #[test]
    fn advertise_rewrites_host_and_keeps_bound_port() {
        let bound: SocketAddr = "127.0.0.1:4321".parse().unwrap();
        assert_eq!(advertised(bound, None).unwrap(), bound);
        // bare IP: keep the bound port
        assert_eq!(
            advertised(bound, Some("10.0.0.5")).unwrap(),
            "10.0.0.5:4321".parse().unwrap()
        );
        // explicit port: forwarded/mapped deployments override it
        assert_eq!(
            advertised(bound, Some("10.0.0.5:19000")).unwrap(),
            "10.0.0.5:19000".parse().unwrap()
        );
        assert!(advertised(bound, Some("bogus")).is_err());
    }

    #[test]
    fn wildcard_binds_require_a_routable_advertise() {
        let bound: SocketAddr = "0.0.0.0:4321".parse().unwrap();
        assert!(advertised(bound, None).is_err(), "0.0.0.0 must not enter a roster");
        assert_eq!(
            advertised(bound, Some("192.168.1.9")).unwrap(),
            "192.168.1.9:4321".parse().unwrap()
        );
    }
}
