//! `coded-graph` — CLI for the coded distributed graph-analytics framework.
//!
//! ```text
//! coded-graph fig5      [--n 300] [--p 0.1] [--k 5] [--trials 20] [--seed 2018]
//! coded-graph scenario  --id 1|2|3|4 [--scale S] [--full] [--seed 7]
//! coded-graph models    [--n 400] [--k 6] [--trials 8]
//! coded-graph run       --graph er|rb|sbm|pl --n N --k K --r R
//!                       [--p P] [--q Q] [--gamma G] [--program pagerank|sssp]
//!                       [--scheme coded|uncoded] [--iters I] [--cluster]
//! coded-graph cluster   --graph er|rb|sbm|pl --n N --k K --r R
//!                       [--transport inproc|tcp] [--program ...] [--scheme ...]
//!                       [--iters I]
//! coded-graph inspect   --graph er|rb|sbm|pl --n N [--p P] [--q Q] [--gamma G]
//! coded-graph artifacts [--dir artifacts]
//! ```
//!
//! Every experiment harness lives in `coded_graph::experiments`; the CLI is
//! a thin printer. `cargo bench` regenerates the paper's figures through
//! the same harnesses.

use coded_graph::allocation::Allocation;
use coded_graph::analysis::theory;
use coded_graph::coordinator::{
    run_cluster, run_cluster_on, run_rust, EngineConfig, Job, JobReport, Scheme,
};
use coded_graph::experiments::{fig5, models, scenarios};
use coded_graph::graph::{bipartite, er, powerlaw, properties, sbm};
use coded_graph::mapreduce::{ConnectedComponents, PageRank, Sssp, VertexProgram};
use coded_graph::transport::TransportKind;
use coded_graph::util::benchkit::Table;
use coded_graph::util::cli::Args;
use coded_graph::util::rng::DetRng;
use coded_graph::Csr;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("fig5") => cmd_fig5(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("models") => cmd_models(&args),
        Some("run") => cmd_run(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!("coded-graph — coded computing for distributed graph analytics");
    println!("(reproduction of Prakash, Reisizadeh, Pedarsani, Avestimehr 2018)\n");
    println!("subcommands:");
    println!("  fig5       communication-load trade-off (paper Fig 5)");
    println!("  scenario   EC2 PageRank scenarios 1-4 (paper Fig 2 / Fig 7 + SBM)");
    println!("  models     Theorem 1-4 validation sweeps across graph models");
    println!("  run        run one distributed job (pagerank / sssp)");
    println!("  cluster    run a job on the leader/worker cluster (--transport inproc|tcp)");
    println!("  inspect    generate a graph and print its statistics");
    println!("  artifacts  list the AOT artifacts and smoke-run one");
}

fn cmd_fig5(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "p", "k", "trials", "seed"])?;
    let params = fig5::Fig5Params {
        n: args.get_or("n", 300usize)?,
        p: args.get_or("p", 0.1f64)?,
        k: args.get_or("k", 5usize)?,
        trials: args.get_or("trials", 20usize)?,
        seed: args.get_or("seed", 2018u64)?,
    };
    println!(
        "Fig 5: ER(n={}, p={}), K={}, {} trials\n",
        params.n, params.p, params.k, params.trials
    );
    let rows = fig5::run(params);
    let mut t = Table::new(&[
        "r", "uncoded", "coded", "lower-bound", "finite-pred", "gain", "ci95",
    ]);
    for row in &rows {
        t.row(&[
            row.r.to_string(),
            format!("{:.5}", row.uncoded.mean),
            format!("{:.5}", row.coded.mean),
            format!("{:.5}", row.lower_bound),
            format!("{:.5}", row.coded_finite_pred),
            format!("{:.2}x", row.gain()),
            format!("{:.5}", row.coded.ci95()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<(), String> {
    args.check_known(&["id", "scale", "full", "seed"])?;
    let id = args.get_or("id", 2usize)?;
    let scale = if args.has("full") { 1 } else { args.get_or("scale", 6usize)? };
    let seed = args.get_or("seed", 7u64)?;
    let sc = scenarios::scenario(id, scale);
    println!("Scenario {id}: {} (n={}, K={})\n", sc.name, sc.n, sc.k);
    let rows = scenarios::run_scenario_scaled(&sc, seed, scale);
    print_scenario_rows(&rows);
    let (best_r, speedup) = scenarios::speedup_over_naive(&rows);
    let naive = rows.iter().find(|r| r.r == 1).unwrap();
    println!(
        "\nbest r = {best_r}: {:.1}% speedup over naive MapReduce (r=1)",
        speedup * 100.0
    );
    let rs = theory::r_star(
        naive.times.map_s + naive.times.encode_s,
        naive.times.shuffle_s,
    );
    println!("Remark 10 heuristic r* = sqrt(T_shuffle/T_map) = {rs:.2}");
    Ok(())
}

fn print_scenario_rows(rows: &[scenarios::ScenarioRow]) {
    let mut t = Table::new(&[
        "r", "scheme", "map", "encode", "shuffle", "decode", "reduce", "update", "total", "load",
    ]);
    for row in rows {
        t.row(&[
            row.r.to_string(),
            row.scheme.to_string(),
            format!("{:.2}s", row.times.map_s),
            format!("{:.2}s", row.times.encode_s),
            format!("{:.2}s", row.times.shuffle_s),
            format!("{:.2}s", row.times.decode_s),
            format!("{:.2}s", row.times.reduce_s),
            format!("{:.2}s", row.times.update_s),
            format!("{:.2}s", row.total_s),
            format!("{:.5}", row.load),
        ]);
    }
    t.print();
}

fn cmd_models(args: &Args) -> Result<(), String> {
    args.check_known(&["n", "k", "trials", "seed", "p", "q", "gamma"])?;
    let params = models::SweepParams {
        n: args.get_or("n", 400usize)?,
        k: args.get_or("k", 6usize)?,
        trials: args.get_or("trials", 8usize)?,
        seed: args.get_or("seed", 99u64)?,
        p: args.get_or("p", 0.2f64)?,
        q: args.get_or("q", 0.05f64)?,
        gamma: args.get_or("gamma", 2.5f64)?,
    };
    for model in [models::Model::Er, models::Model::Rb, models::Model::Sbm, models::Model::Pl] {
        println!("\n=== {model} model (Theorems 1-4) ===");
        let mut t = Table::new(&["r", "uncoded", "coded", "gain", "thm-upper", "thm-lower"]);
        for row in models::sweep(model, params) {
            t.row(&[
                row.r.to_string(),
                format!("{:.5}", row.uncoded.mean),
                format!("{:.5}", row.coded.mean),
                format!("{:.2}x", row.gain()),
                format!("{:.5}", row.predicted_upper),
                format!("{:.5}", row.predicted_lower),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn build_graph(args: &Args) -> Result<Csr, String> {
    let n = args.get_or("n", 1000usize)?;
    let seed = args.get_or("seed", 1u64)?;
    let mut rng = DetRng::seed(seed);
    match args.get("graph").unwrap_or("er") {
        "er" => Ok(er::er(n, args.get_or("p", 0.1f64)?, &mut rng)),
        "rb" => Ok(bipartite::rb(n / 2, n - n / 2, args.get_or("q", 0.05f64)?, &mut rng)),
        "sbm" => Ok(sbm::sbm(
            n / 2,
            n - n / 2,
            args.get_or("p", 0.2f64)?,
            args.get_or("q", 0.05f64)?,
            &mut rng,
        )),
        "pl" => Ok(powerlaw::pl(
            n,
            powerlaw::PlParams {
                gamma: args.get_or("gamma", 2.3f64)?,
                max_degree: 100_000,
                rho_scale: args.get_or("rho-scale", 1.0f64)?,
            },
            &mut rng,
        )),
        other => Err(format!("unknown graph model {other:?}")),
    }
}

fn parse_scheme(args: &Args) -> Result<Scheme, String> {
    match args.get("scheme").unwrap_or("coded") {
        "coded" => Ok(Scheme::Coded),
        "uncoded" => Ok(Scheme::Uncoded),
        "coded-combined" => Ok(Scheme::CodedCombined),
        "uncoded-combined" => Ok(Scheme::UncodedCombined),
        other => Err(format!("unknown scheme {other:?}")),
    }
}

fn parse_program(args: &Args) -> Result<Box<dyn VertexProgram>, String> {
    Ok(match args.get("program").unwrap_or("pagerank") {
        "pagerank" => Box::new(PageRank::default()),
        "sssp" => Box::new(Sssp::hashed(args.get_or("source", 0u32)?)),
        "cc" => Box::new(ConnectedComponents),
        other => return Err(format!("unknown program {other:?}")),
    })
}

#[allow(clippy::too_many_arguments)]
fn print_job_summary(
    report: &JobReport,
    program: &dyn VertexProgram,
    g: &Csr,
    k: usize,
    r: usize,
    scheme: Scheme,
    iters: usize,
) {
    println!(
        "{} x{} iterations on n={} m={} K={k} r={r} ({scheme})",
        program.name(),
        iters,
        g.n(),
        g.m()
    );
    let t = report.summed_times();
    println!(
        "sim times: map={:.3}s encode={:.3}s shuffle={:.3}s decode={:.3}s reduce={:.3}s update={:.3}s total={:.3}s",
        t.map_s, t.encode_s, t.shuffle_s, t.decode_s, t.reduce_s, t.update_s, t.total()
    );
    println!(
        "mean normalized shuffle load: {:.6}",
        report.mean_normalized_load(g.n())
    );
    let mut top: Vec<(usize, f64)> = report.final_state.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top-5 final states: {:?}", &top[..5.min(top.len())]);
}

fn cmd_run(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "graph", "n", "k", "r", "p", "q", "gamma", "rho-scale", "seed", "program", "scheme", "iters",
        "cluster", "source",
    ])?;
    let g = build_graph(args)?;
    let k = args.get_or("k", 5usize)?;
    let r = args.get_or("r", 2usize)?;
    let iters = args.get_or("iters", 3usize)?;
    let scheme = parse_scheme(args)?;
    let alloc = Allocation::er_scheme(g.n(), k, r);
    let program = parse_program(args)?;
    let cfg = EngineConfig { scheme, ..Default::default() };
    let job = Job { graph: &g, alloc: &alloc, program: &*program };
    let report = if args.has("cluster") {
        println!("driver: in-process cluster ({k} workers + leader)");
        run_cluster(&job, &cfg, iters)
    } else {
        println!("driver: phase engine");
        run_rust(&job, &cfg, iters)
    };
    print_job_summary(&report, &*program, &g, k, r, scheme, iters);
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "graph", "n", "k", "r", "p", "q", "gamma", "rho-scale", "seed", "program", "scheme", "iters",
        "transport", "source",
    ])?;
    let g = build_graph(args)?;
    let k = args.get_or("k", 5usize)?;
    let r = args.get_or("r", 2usize)?;
    let iters = args.get_or("iters", 3usize)?;
    let scheme = parse_scheme(args)?;
    let transport: TransportKind = args
        .get("transport")
        .unwrap_or("inproc")
        .parse()?;
    let alloc = Allocation::er_scheme(g.n(), k, r);
    let program = parse_program(args)?;
    let cfg = EngineConfig { scheme, ..Default::default() };
    let job = Job { graph: &g, alloc: &alloc, program: &*program };
    println!("driver: cluster over {transport} ({k} workers + leader)");
    let report = run_cluster_on(&job, &cfg, iters, transport);
    print_job_summary(&report, &*program, &g, k, r, scheme, iters);
    let wall: f64 = report.iterations.iter().map(|m| m.wall_s).sum();
    println!("real wall time across iterations: {wall:.3}s");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), String> {
    args.check_known(&["graph", "n", "p", "q", "gamma", "rho-scale", "seed"])?;
    let g = build_graph(args)?;
    let s = properties::stats(&g);
    println!("n={} m={} density={:.5}", s.n, s.m, s.density);
    println!(
        "degree: min={} mean={:.2} max={} isolated={:.2}%",
        s.min_degree,
        s.mean_degree,
        s.max_degree,
        s.isolated_frac * 100.0
    );
    if let Some(gamma) = properties::powerlaw_exponent_mle(&g, 3) {
        println!("power-law exponent (MLE, d>=3): {gamma:.2}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(args: &Args) -> Result<(), String> {
    args.check_known(&["dir"])?;
    Err("this binary was built without the `xla` feature; rebuild with \
         `--features xla` (and a vendored xla crate) to load PJRT artifacts"
        .into())
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<(), String> {
    args.check_known(&["dir"])?;
    let dir = std::path::PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let rt = coded_graph::runtime::PjrtRuntime::load(&dir).map_err(|e| e.to_string())?;
    println!("artifacts in {}:", dir.display());
    for e in &rt.manifest().entries {
        let shapes: Vec<String> = e.inputs.iter().map(|(s, _)| format!("{s:?}")).collect();
        println!("  {:28} inputs: {}", e.name, shapes.join(" x "));
    }
    // smoke-run the largest pagerank block
    if let Some((entry, b)) = rt.manifest().best_block("pagerank_block") {
        let name = entry.name.clone();
        let a = vec![1.0f32 / b as f32; b * b];
        let x = vec![1.0f32; b];
        let y = rt
            .execute_f32(&name, &[
                coded_graph::runtime::client::Arg::F32(&a),
                coded_graph::runtime::client::Arg::F32(&x),
            ])
            .map_err(|e| e.to_string())?;
        println!("\nsmoke: {name}(uniform) -> y[0] = {} (want 1.0)", y[0]);
    }
    Ok(())
}
