# Allow `pytest python/tests` from the repo root: tests import the
# build-time `compile` package which lives next to this file.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
