"""L1 Pallas kernel: column-XOR fold of a segment table.

The Encode stage of the paper's coded Shuffle (SIV-A): a sender arranges the
segments it owes the other ``r`` members of a multicast group in an
``r x m`` table and broadcasts the XOR of each non-empty column. Missing
entries are zero-padded, and ``x ^ 0 = x``, so a dense XOR fold over a
zero-padded table is exactly the paper's encoder.

Segments are chunked into 32-bit words (int32 lanes XOR natively on TPU
VPU); the column axis is tiled so large tables stream through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_fold_kernel(t_ref, o_ref, *, rows: int):
    acc = t_ref[0, :]
    for i in range(1, rows):  # rows is static at trace time
        acc = jnp.bitwise_xor(acc, t_ref[i, :])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_cols",))
def xor_fold(table, *, block_cols: int = 1024):
    """XOR-fold the rows of an ``(r, m)`` int32 table into an ``(m,)`` row.

    ``m`` must be a multiple of ``block_cols`` (callers zero-pad; the pad
    columns fold to 0 and are dropped by the consumer).
    """
    r, m = table.shape
    block_cols = min(block_cols, m)
    assert m % block_cols == 0, (m, block_cols)
    kernel = functools.partial(_xor_fold_kernel, rows=r)
    return pl.pallas_call(
        kernel,
        grid=(m // block_cols,),
        in_specs=[pl.BlockSpec((r, block_cols), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block_cols,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(table)
