# L1: Pallas kernels for the paper's compute hot-spots.
#
# masked_spmv — PageRank Map phase as MXU-shaped tile matmul
# minplus     — SSSP relaxation as tropical (min,+) tile product
# xor_fold    — coded-shuffle Encode stage (column XOR of segment tables)
# ref         — pure-jnp oracles for all of the above

from . import masked_spmv, minplus, ref, xor_fold  # noqa: F401
