"""L1 Pallas kernel: blocked (masked) sparse-matrix/vector product.

This is the PageRank Map phase recast for the MXU (see DESIGN.md
§Hardware-Adaptation): the paper's per-edge Python dict walk
``v_{i,j} = Pi(j) * P(j->i)`` becomes a dense-tile matmul

    y[i_tile] += A_norm[i_tile, j_tile] @ x[j_tile]

where ``A_norm[i, j] = 1{(j,i) in E} / deg(j)`` is the column-normalized
adjacency tile each worker materializes for its (Reduce-rows x Mapped-cols)
block. Tiles are BlockSpec'd so the HBM->VMEM schedule is explicit; the
per-tile body is a single MXU-shaped matmul.

The kernel MUST be lowered with ``interpret=True`` on this CPU image: real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(a_ref, x_ref, o_ref):
    """One (bi, bj) grid step: accumulate a_tile @ x_tile into o_tile.

    The j-loop (``program_id(1)``) is the reduction dimension; the output
    tile is revisited once per j step, so we zero it on the first visit and
    accumulate afterwards.
    """

    @pl.when(pl.program_id(1) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def masked_spmv(a, x, *, block_rows: int = 128, block_cols: int = 128):
    """Compute ``a @ x`` with a tiled Pallas kernel.

    Args:
      a: ``(m, n)`` float32 tile-aligned matrix (``m % block_rows == 0`` and
        ``n % block_cols == 0``; the caller pads).
      x: ``(n, 1)`` float32 vector (kept 2-D so the tile body is a matmul,
        which is what the MXU wants).
      block_rows / block_cols: VMEM tile shape. 128x128 f32 keeps the
        working set (a-tile + x-tile + o-tile ~ 66 KiB) far under VMEM.

    Returns:
      ``(m, 1)`` float32 product.
    """
    m, n = a.shape
    assert m % block_rows == 0, (m, block_rows)
    assert n % block_cols == 0, (n, block_cols)
    assert x.shape == (n, 1), x.shape
    grid = (m // block_rows, n // block_cols)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((block_cols, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=True,
    )(a, x)
