"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has a reference here with the same signature;
pytest sweeps shapes (hypothesis) and asserts allclose/exact equality.
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_spmv_ref(a, x):
    """``a @ x`` — oracle for kernels.masked_spmv.masked_spmv."""
    return jnp.dot(a, x, preferred_element_type=jnp.float32)


def minplus_mv_ref(w, d):
    """``min_j(w[i,j] + d[j])`` — oracle for kernels.minplus.minplus_mv."""
    return jnp.min(w + jnp.transpose(d), axis=1, keepdims=True)


def xor_fold_ref(table):
    """Column XOR fold — oracle for kernels.xor_fold.xor_fold."""
    acc = table[0, :]
    for i in range(1, table.shape[0]):
        acc = jnp.bitwise_xor(acc, table[i, :])
    return acc


def pagerank_iteration_ref(a_norm, pi, damping, n):
    """One full PageRank iteration (paper eq. (4)) on a dense matrix.

    ``a_norm[i, j] = P(j -> i)`` so the update is
    ``pi' = (1 - d) * a_norm @ pi + d / n``.
    """
    return (1.0 - damping) * jnp.dot(a_norm, pi) + damping / n


def sssp_relax_ref(w, dist):
    """One SSSP relaxation sweep (paper eq. (5)) including self-retention."""
    return jnp.minimum(dist, jnp.min(w + jnp.transpose(dist), axis=1, keepdims=True))
