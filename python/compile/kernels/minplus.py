"""L1 Pallas kernel: blocked tropical (min,+) matrix-vector product.

This is the SSSP Map/Reduce hot loop (paper Example 2) as tile algebra:

    y[i] = min_j ( W[i, j] + d[j] )

with ``W[i, j] = t(j, i)`` the edge weight (``+inf`` for non-edges). Each
grid step loads a ``(bi, bj)`` weight tile and a ``(bj, 1)`` distance tile
into VMEM, forms the broadcast sum and folds a min over the j axis; the
output tile carries a running min across j steps (initialized to +inf on
the first visit).

Lowered with ``interpret=True`` (CPU image; see masked_spmv.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 3.0e38  # stand-in for +inf that survives arithmetic (python float:
# a jnp constant would be captured by the kernel closure, which pallas rejects)


def _minplus_kernel(w_ref, d_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    # (bi, bj) + (1, bj) broadcast, then min over the j axis -> (bi, 1).
    contrib = jnp.min(
        w_ref[...] + jnp.transpose(d_ref[...]), axis=1, keepdims=True
    )
    o_ref[...] = jnp.minimum(o_ref[...], contrib)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def minplus_mv(w, d, *, block_rows: int = 128, block_cols: int = 128):
    """Tropical product ``min_j (w[i, j] + d[j])`` over tile-aligned inputs.

    Args:
      w: ``(m, n)`` float32 weight matrix, ``INF`` marks non-edges.
      d: ``(n, 1)`` float32 current distances.

    Returns:
      ``(m, 1)`` float32 relaxed distances (pure contribution; the caller
      still mins with the previous distance of row vertices).
    """
    m, n = w.shape
    assert m % block_rows == 0 and n % block_cols == 0, (w.shape,)
    assert d.shape == (n, 1), d.shape
    grid = (m // block_rows, n // block_cols)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((block_cols, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=True,
    )(w, d)
