"""L2: JAX compute-graph functions for the paper's workloads.

These are the functions that get AOT-lowered (aot.py) into HLO-text
artifacts the rust coordinator executes via PJRT. Each one composes the L1
Pallas kernels so the kernels lower into the same HLO module.

Shapes are static per artifact (PJRT executables are shape-monomorphic);
``aot.py`` lowers a small set of tile variants and records them in
``artifacts/manifest.json``. The rust runtime pads worker blocks up to the
nearest variant.

Workloads
---------
* ``pagerank_block_step`` — the Map-phase hot loop of one PageRank
  iteration restricted to a worker's (Reduce-rows x Mapped-cols) block:
  partial sums ``y = A_norm @ pi_block`` (the damping affine is applied by
  the Reducer after summing partials across blocks).
* ``pagerank_full_iteration`` — a whole small-graph iteration
  ``pi' = (1-d) A pi + d/n`` (single-machine reference path; used by the
  quickstart example and as the r = K degenerate case).
* ``sssp_block_relax`` — tropical block product for one SSSP sweep.
* ``encode_xor_fold`` — the coded-shuffle Encode stage on a segment table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.masked_spmv import masked_spmv
from .kernels.minplus import minplus_mv
from .kernels.xor_fold import xor_fold


def pagerank_block_step(a_norm, pi):
    """Partial PageRank sums for one worker block: ``a_norm @ pi``.

    ``a_norm`` is the column-normalized adjacency block (``(m, n)`` f32,
    ``a_norm[i, j] = 1{(j,i) in E}/deg(j)``), ``pi`` the ``(n, 1)`` rank
    slice of the Mapped vertices. Output is the ``(m, 1)`` vector of
    intermediate-value sums for the block's Reduce rows.
    """
    return (masked_spmv(a_norm, pi),)


def pagerank_full_iteration(a_norm, pi, damping):
    """One full PageRank iteration on a dense normalized adjacency.

    ``pi' = (1 - d) * (A_norm @ pi) + d / n`` with ``n`` taken from the
    static shape. Composes the L1 spmv kernel with the affine tail so the
    whole iteration is a single fused HLO module.
    """
    n = a_norm.shape[0]
    y = masked_spmv(a_norm, pi)
    return ((1.0 - damping) * y + damping / n,)


def sssp_block_relax(w, dist):
    """Tropical block product ``min_j(w[i,j] + dist[j])`` for SSSP."""
    return (minplus_mv(w, dist),)


def encode_xor_fold(table):
    """Coded-shuffle Encode: XOR-fold segment-table rows into one packet row."""
    return (xor_fold(table),)


def pagerank_multi_iteration(a_norm, pi, damping, *, iters: int = 8):
    """`iters` fused PageRank iterations via `lax.scan`.

    Demonstrates L2 composition: the L1 spmv kernel is the scan body, so
    the whole fixed-point loop lowers into ONE HLO module (no per-iteration
    host round-trips). Used by the r = K degenerate path and the runtime
    bench.
    """
    import jax.lax as lax

    n = a_norm.shape[0]

    def body(carry, _):
        y = masked_spmv(a_norm, carry)
        return (1.0 - damping) * y + damping / n, None

    out, _ = lax.scan(body, pi, None, length=iters)
    return (out,)


# --- lowering entry points -------------------------------------------------
# name -> (callable, example-arg builder). Shapes are the static variants
# aot.py emits; keep rust/src/runtime/manifest.rs in sync via manifest.json.


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lowering_specs(block: int = 256, xor_cols: int = 1024):
    """The artifact set: ``{name: (fn, example_args)}``.

    ``block`` is the square tile edge for the graph workloads; XOR tables
    are lowered once per row count r in 2..=7 (the coded scheme sends
    r-segment XORs; K <= 8 in every experiment's multicast groups).
    """
    specs = {
        f"pagerank_block_{block}": (
            pagerank_block_step,
            (_f32(block, block), _f32(block, 1)),
        ),
        f"pagerank_full_{block}": (
            pagerank_full_iteration,
            (_f32(block, block), _f32(block, 1), _f32()),
        ),
        f"sssp_block_{block}": (
            sssp_block_relax,
            (_f32(block, block), _f32(block, 1)),
        ),
        f"pagerank_scan8_{block}": (
            pagerank_multi_iteration,
            (_f32(block, block), _f32(block, 1), _f32()),
        ),
    }
    for r in range(2, 8):
        specs[f"xor_fold_r{r}_m{xor_cols}"] = (
            encode_xor_fold,
            (_i32(r, xor_cols),),
        )
    return specs
