"""AOT lowering: JAX (L2 + L1) -> HLO TEXT artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Python is never on the request path; the rust binary is self-contained
after the artifacts are built.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import lowering_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_entry(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def build(out_dir: str, block: int, xor_cols: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "entries": []}
    for name, (fn, args) in sorted(lowering_specs(block, xor_cols).items()):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [_arg_entry(a) for a in args],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        )
        print(f"  aot: {name:28s} -> {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--block", type=int, default=256, help="square tile edge")
    ap.add_argument("--xor-cols", type=int, default=1024)
    args = ap.parse_args()
    manifest = build(args.out_dir, args.block, args.xor_cols)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
