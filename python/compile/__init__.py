# Build-time-only package: JAX/Pallas authoring + AOT lowering.
# Never imported on the request path — rust loads artifacts/*.hlo.txt.
