"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps tile-aligned shapes and data; each kernel must match its
oracle to float tolerance (exactly, for the integer XOR kernel).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.masked_spmv import masked_spmv
from compile.kernels.minplus import minplus_mv, INF
from compile.kernels.xor_fold import xor_fold

# interpret-mode pallas is slow; keep example counts deliberate.
KERNEL_SETTINGS = dict(max_examples=12, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


class TestMaskedSpmv:
    @settings(**KERNEL_SETTINGS)
    @given(
        bi=st.sampled_from([32, 64, 128]),
        bj=st.sampled_from([32, 64, 128]),
        gi=st.integers(1, 3),
        gj=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_tilings(self, bi, bj, gi, gj, seed):
        rng = _rng(seed)
        m, n = bi * gi, bj * gj
        a = rng.random((m, n), dtype=np.float32)
        x = rng.random((n, 1), dtype=np.float32)
        got = masked_spmv(a, x, block_rows=bi, block_cols=bj)
        np.testing.assert_allclose(got, ref.masked_spmv_ref(a, x), rtol=1e-5, atol=1e-5)

    def test_zero_matrix(self):
        a = np.zeros((128, 128), dtype=np.float32)
        x = np.ones((128, 1), dtype=np.float32)
        np.testing.assert_array_equal(np.asarray(masked_spmv(a, x)), 0.0)

    def test_identity(self):
        n = 128
        a = np.eye(n, dtype=np.float32)
        x = np.arange(n, dtype=np.float32).reshape(n, 1)
        np.testing.assert_allclose(masked_spmv(a, x), x, rtol=1e-6)

    def test_column_stochastic_preserves_mass(self):
        # A column-normalized adjacency (no dangling nodes) preserves sum(x):
        # the PageRank mass-conservation property the Map phase relies on.
        rng = _rng(7)
        n = 256
        a = (rng.random((n, n)) < 0.2).astype(np.float32)
        a[0, :] += (a.sum(axis=0) == 0)  # patch dangling columns
        a /= a.sum(axis=0, keepdims=True)
        x = rng.random((n, 1), dtype=np.float32)
        y = np.asarray(masked_spmv(a.astype(np.float32), x))
        np.testing.assert_allclose(y.sum(), x.sum(), rtol=1e-4)

    def test_rejects_misaligned_shapes(self):
        a = np.zeros((100, 128), dtype=np.float32)
        x = np.zeros((128, 1), dtype=np.float32)
        with pytest.raises(AssertionError):
            masked_spmv(a, x)


class TestMinplus:
    @settings(**KERNEL_SETTINGS)
    @given(
        bi=st.sampled_from([32, 64, 128]),
        gi=st.integers(1, 3),
        gj=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_tilings(self, bi, gi, gj, seed):
        rng = _rng(seed)
        m, n = bi * gi, bi * gj
        w = rng.random((m, n), dtype=np.float32) * 10.0
        d = rng.random((n, 1), dtype=np.float32) * 10.0
        got = minplus_mv(w, d, block_rows=bi, block_cols=bi)
        np.testing.assert_allclose(got, ref.minplus_mv_ref(w, d), rtol=1e-6)

    @settings(**KERNEL_SETTINGS)
    @given(density=st.floats(0.01, 0.5), seed=st.integers(0, 2**31 - 1))
    def test_inf_nonedges_ignored(self, density, seed):
        # Non-edges are encoded as INF; they must never win the min.
        rng = _rng(seed)
        n = 128
        w = np.full((n, n), INF, dtype=np.float32)
        mask = rng.random((n, n)) < density
        w[mask] = rng.random(mask.sum()).astype(np.float32)
        d = rng.random((n, 1), dtype=np.float32)
        got = np.asarray(minplus_mv(w, d))
        want = np.asarray(ref.minplus_mv_ref(w, d))
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # rows with no edges stay "infinite"
        empty_rows = ~mask.any(axis=1)
        assert (got[empty_rows, 0] > INF / 2).all()

    def test_single_source_step(self):
        # One relaxation from a single source on a 3-path embedded in a tile.
        n = 128
        w = np.full((n, n), INF, dtype=np.float32)
        w[1, 0] = 2.0  # edge 0 -> 1 weight 2
        w[2, 1] = 3.0  # edge 1 -> 2 weight 3
        d = np.full((n, 1), INF, dtype=np.float32)
        d[0] = 0.0
        got = np.asarray(ref.sssp_relax_ref(w, d))
        assert got[0, 0] == 0.0
        assert got[1, 0] == pytest.approx(2.0)
        assert got[2, 0] > INF / 2  # two hops need two sweeps
        got_k = np.minimum(d, np.asarray(minplus_mv(w, d)))
        np.testing.assert_allclose(got_k[:3], got[:3], rtol=1e-6)


class TestXorFold:
    @settings(**KERNEL_SETTINGS)
    @given(
        r=st.integers(2, 7),
        g=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, r, g, seed):
        rng = _rng(seed)
        m = 256 * g
        t = rng.integers(-(2**31), 2**31 - 1, (r, m), dtype=np.int32)
        got = xor_fold(t, block_cols=256)
        np.testing.assert_array_equal(got, ref.xor_fold_ref(t))

    @settings(**KERNEL_SETTINGS)
    @given(r=st.integers(2, 7), seed=st.integers(0, 2**31 - 1))
    def test_self_inverse(self, r, seed):
        # XOR-folding a table with a duplicated row pair cancels that pair:
        # the algebraic property the coded-shuffle decoder relies on.
        rng = _rng(seed)
        m = 1024
        t = rng.integers(-(2**31), 2**31 - 1, (r, m), dtype=np.int32)
        doubled = np.vstack([t, t])
        got = np.asarray(xor_fold(doubled))
        np.testing.assert_array_equal(got, np.zeros(m, dtype=np.int32))

    def test_zero_padding_is_identity(self):
        rng = _rng(3)
        t = rng.integers(-(2**31), 2**31 - 1, (3, 1024), dtype=np.int32)
        padded = np.vstack([t, np.zeros((2, 1024), dtype=np.int32)])
        np.testing.assert_array_equal(
            np.asarray(xor_fold(padded)), np.asarray(xor_fold(t))
        )
