"""L2 model correctness: composed jit functions vs whole-graph oracles.

Checks the two distributed-decomposition identities the rust coordinator
relies on:

* summing per-block ``pagerank_block_step`` partials over a column
  partition of the Mapped vertices reproduces the full iteration, and
* min-folding per-block ``sssp_block_relax`` partials reproduces the full
  relaxation sweep.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=8, deadline=None)


def _norm_adjacency(rng, n, p):
    a = (rng.random((n, n)) < p).astype(np.float32)
    np.fill_diagonal(a, 0.0)
    a = np.maximum(a, a.T)  # undirected, as in the paper
    deg = a.sum(axis=0)
    deg[deg == 0] = 1.0
    return (a / deg).astype(np.float32)


class TestPageRank:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.05, 0.5))
    def test_full_iteration_matches_ref(self, seed, p):
        rng = np.random.default_rng(seed)
        n = 256
        a = _norm_adjacency(rng, n, p)
        pi = np.full((n, 1), 1.0 / n, dtype=np.float32)
        d = np.float32(0.15)
        (got,) = model.pagerank_full_iteration(a, pi, d)
        want = ref.pagerank_iteration_ref(a, pi, d, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(2, 4))
    def test_block_partials_sum_to_full(self, seed, blocks):
        # Column-partition the Mapped vertices into `blocks` groups (this is
        # exactly how worker subgraphs tile the adjacency) and check the
        # partial sums recombine to the full product.
        rng = np.random.default_rng(seed)
        nb = 128
        n = nb * blocks
        a = _norm_adjacency(rng, n, 0.1)
        pi = rng.random((n, 1), dtype=np.float32)
        partial = np.zeros((n, 1), dtype=np.float32)
        for b in range(blocks):
            cols = slice(b * nb, (b + 1) * nb)
            (y,) = model.pagerank_block_step(
                np.ascontiguousarray(a[:, cols]), np.ascontiguousarray(pi[cols])
            )
            partial += np.asarray(y)
        np.testing.assert_allclose(
            partial, ref.masked_spmv_ref(a, pi), rtol=1e-4, atol=1e-6
        )

    def test_stationary_under_iteration(self):
        # Iterating to convergence yields a fixed point of the update map.
        rng = np.random.default_rng(0)
        n = 128
        a = _norm_adjacency(rng, n, 0.2)
        pi = np.full((n, 1), 1.0 / n, dtype=np.float32)
        d = np.float32(0.15)
        for _ in range(60):
            (pi,) = model.pagerank_full_iteration(a, pi, d)
        (nxt,) = model.pagerank_full_iteration(a, pi, d)
        np.testing.assert_allclose(nxt, pi, atol=1e-6)
        np.testing.assert_allclose(np.asarray(pi).sum(), 1.0, rtol=1e-3)


class TestSssp:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(2, 4))
    def test_block_partials_min_to_full(self, seed, blocks):
        rng = np.random.default_rng(seed)
        nb = 128
        n = nb * blocks
        from compile.kernels.minplus import INF

        w = np.full((n, n), INF, dtype=np.float32)
        mask = rng.random((n, n)) < 0.05
        w[mask] = (rng.random(mask.sum()) * 10).astype(np.float32)
        dist = (rng.random((n, 1)) * 5).astype(np.float32)
        folded = np.full((n, 1), INF, dtype=np.float32)
        for b in range(blocks):
            cols = slice(b * nb, (b + 1) * nb)
            (y,) = model.sssp_block_relax(
                np.ascontiguousarray(w[:, cols]), np.ascontiguousarray(dist[cols])
            )
            folded = np.minimum(folded, np.asarray(y))
        np.testing.assert_allclose(
            folded, ref.minplus_mv_ref(w, dist), rtol=1e-6
        )


class TestMultiIteration:
    def test_scan_matches_repeated_single(self):
        rng = np.random.default_rng(5)
        n = 128
        a = _norm_adjacency(rng, n, 0.15)
        pi = np.full((n, 1), 1.0 / n, dtype=np.float32)
        d = np.float32(0.15)
        (scan_out,) = model.pagerank_multi_iteration(a, pi, d, iters=8)
        step = pi
        for _ in range(8):
            (step,) = model.pagerank_full_iteration(a, step, d)
        np.testing.assert_allclose(scan_out, step, rtol=1e-5, atol=1e-7)

    def test_scan_lowers_to_single_module(self):
        import jax
        from compile import aot

        spec = model.lowering_specs(block=128)["pagerank_scan8_128"]
        fn, args = spec
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule")
        # one while-loop, not 8 unrolled matmuls at top level
        assert "while" in text
