"""AOT lowering path: every artifact lowers to parseable HLO text.

These tests exercise the exact code `make artifacts` runs, in-memory, so a
broken lowering fails fast in pytest rather than at rust runtime.
"""

import json
import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def specs():
    return model.lowering_specs(block=128, xor_cols=256)


def test_spec_names_are_unique(specs):
    assert len(specs) == len(set(specs))


def test_every_spec_lowers_to_hlo_text(specs):
    for name, (fn, args) in specs.items():
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule"), name
        # The 0.5.1 text parser chokes on nothing we emit: ROOT + params.
        assert "ROOT" in text, name
        for i in range(len(args)):
            assert f"parameter({i})" in text, (name, i)


def test_build_writes_manifest(tmp_path):
    manifest = aot.build(str(tmp_path), block=128, xor_cols=256)
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    assert on_disk["format"] == "hlo-text"
    names = {e["name"] for e in on_disk["entries"]}
    assert "pagerank_block_128" in names
    assert "xor_fold_r7_m256" in names
    for e in on_disk["entries"]:
        path = tmp_path / e["file"]
        assert path.exists()
        assert os.path.getsize(path) == e["bytes"]


def test_manifest_shapes_match_specs(tmp_path):
    manifest = aot.build(str(tmp_path), block=128, xor_cols=256)
    specs = model.lowering_specs(block=128, xor_cols=256)
    for e in manifest["entries"]:
        _, args = specs[e["name"]]
        assert [list(a.shape) for a in args] == [i["shape"] for i in e["inputs"]]
