#!/usr/bin/env python3
"""Diff a bench-smoke JSON document against the committed snapshot.

Usage: bench_compare.py CURRENT.json SNAPSHOT.json

Both files are `BenchJson` documents (`{"suite": ..., "records": [...]}`)
as written by `cargo bench --bench shuffle_micro -- --smoke --json PATH`.
Records are matched by section name (`bench`) plus every non-timing
parameter (n, r, failures, ...); timing fields (`*_s`) are reported as
percent deltas, current vs snapshot.

This is a trend report, not a gate: machines differ, CI hosts are noisy,
and the snapshot is refreshed per PR (`make bench-snapshot`). The script
exits 0 unless a file is unreadable or structurally invalid. An empty
snapshot (`{"records": []}`) means "no baseline yet" and is reported as
such. Stdlib only — no third-party dependencies.
"""

import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("records"), list):
        raise SystemExit(f"{path}: not a BenchJson document (missing 'records' list)")
    return doc["records"]


def is_timing(key, value):
    return isinstance(value, (int, float)) and key.endswith("_s")


def record_key(rec):
    """Identity of a record: its section plus all non-timing parameters."""
    params = tuple(
        sorted((k, v) for k, v in rec.items() if k != "bench" and not is_timing(k, v))
    )
    return (rec.get("bench", "?"), params)


def main(argv):
    if len(argv) != 3:
        raise SystemExit(__doc__.strip().splitlines()[2])
    current, snapshot = load(argv[1]), load(argv[2])
    if not snapshot:
        print(f"bench-compare: snapshot {argv[2]} has no records (no baseline yet) — skipping")
        return 0
    base = {record_key(r): r for r in snapshot}
    matched = missing = 0
    for rec in current:
        key = record_key(rec)
        name = key[0]
        old = base.pop(key, None)
        if old is None:
            missing += 1
            print(f"  {name:<22} (no matching snapshot record — params changed or section is new)")
            continue
        matched += 1
        for field, val in rec.items():
            if not is_timing(field, val):
                continue
            ref = old.get(field)
            if not isinstance(ref, (int, float)) or ref == 0:
                continue
            delta = (val / ref - 1.0) * 100.0
            flag = "  <-- " + ("slower" if delta > 10 else "faster") if abs(delta) > 10 else ""
            print(f"  {name:<22} {field:<18} {ref * 1e3:9.3f} ms -> {val * 1e3:9.3f} ms  {delta:+7.1f}%{flag}")
    for key in base:
        print(f"  {key[0]:<22} (snapshot record has no current counterpart)")
    print(
        f"bench-compare: {matched} matched, {missing} unmatched, "
        f"{len(base)} snapshot-only (informational — not a gate)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
