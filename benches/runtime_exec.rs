//! Bench: PJRT runtime — artifact compile cost, tiled Reduce throughput
//! (AOT JAX/Pallas masked-SpMV vs the pure-rust fold), and the XOR-fold
//! Encode on the accelerator vs the rust encoder. Quantifies what the
//! three-layer split costs/buys on this CPU backend (on TPU the tile
//! matmul hits the MXU; see DESIGN.md §Hardware-Adaptation).
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo bench --bench runtime_exec
//! ```

use coded_graph::allocation::Allocation;
use coded_graph::coordinator::{
    prepare, run_iteration_scratch, Backend, EngineConfig, EngineScratch, Job, Scheme, XlaKind,
};
use coded_graph::graph::er::er;
use coded_graph::mapreduce::{PageRank, VertexProgram};
use coded_graph::runtime::{BlockExecutor, PjrtRuntime};
use coded_graph::util::benchkit::{Bench, Table};
use coded_graph::util::rng::DetRng;
use coded_graph::Vertex;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(0);
    }
    let (rt, t_load) = Bench::once(|| PjrtRuntime::load(&artifacts));
    let rt = rt?;
    println!("# PJRT runtime benches (CPU backend)\n");
    println!("runtime load (manifest parse + client init): {:.1} ms", t_load * 1e3);

    // compile cost: first call compiles, later calls hit the cache
    let mut exec = BlockExecutor::new(&rt)?;
    let b = exec.block;
    let g = er(2048, 0.05, &mut DetRng::seed(5));
    let n = g.n();
    let prog = PageRank::default();
    let x: Vec<f32> = (0..n as Vertex)
        .map(|j| (1.0 / n as f64 / g.degree(j).max(1) as f64) as f32)
        .collect();
    let rows: Vec<Vertex> = (0..n as Vertex).collect();
    let (_, t_first) = Bench::once(|| exec.pagerank_rows(&g, &rows, &x));
    println!("first tiled pagerank_rows (incl. XLA compile of {b}x{b} tile): {:.1} ms", t_first * 1e3);

    let bench = Bench::new(1, 5);
    let m_pjrt = bench.run(|| exec.pagerank_rows(&g, &rows, &x).unwrap());
    let flops = 2.0 * (g.m() as f64) * 2.0; // masked-dense: count edges twice
    println!(
        "steady tiled pagerank_rows: {:.1} ms ({} tile execs/iter)",
        m_pjrt.mean_ms(),
        exec.executions / (m_pjrt.iters + 2)
    );

    // pure-rust reduce for comparison
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let m_rust = bench.run(|| {
        let mut acc = vec![0.0f64; n];
        for i in 0..n as Vertex {
            let mut s = 0.0;
            for &j in g.neighbors(i) {
                s += state[j as usize] / g.degree(j) as f64;
            }
            acc[i as usize] = s;
        }
        acc
    });
    println!("pure-rust sparse fold:      {:.1} ms", m_rust.mean_ms());
    println!(
        "ratio: {:.1}x (dense-tile PJRT on CPU pays materialization + call overhead;\n        on TPU the same artifact is MXU-bound — the AOT path exists for that target)",
        m_pjrt.mean_s / m_rust.mean_s
    );
    let _ = flops;

    // ---- whole-iteration comparison: rust vs PJRT backend ---------------
    println!("\n## end-to-end iteration (n={n}, K=5, r=2, coded)");
    let alloc = Allocation::er_scheme(n, 5, 2);
    let job = Job { graph: &g, alloc: &alloc, program: &prog };
    let cfg = EngineConfig { scheme: Scheme::Coded, ..Default::default() };
    let prep = prepare(&job, Scheme::Coded);
    let st: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let mut scratch = EngineScratch::new();
    let mut next = vec![0.0f64; n];
    let m_iter_rust = bench.run(|| {
        run_iteration_scratch(&job, &prep, &st, &cfg, &mut Backend::Rust, &mut scratch, &mut next);
        next[0]
    });
    let mut exec2 = BlockExecutor::new(&rt)?;
    let m_iter_pjrt = bench.run(|| {
        let mut backend = Backend::Pjrt { exec: &mut exec2, kind: XlaKind::PageRank };
        run_iteration_scratch(&job, &prep, &st, &cfg, &mut backend, &mut scratch, &mut next);
        next[0]
    });
    let mut t = Table::new(&["backend", "wall/iter (ms)"]);
    t.row(&["rust fold".into(), format!("{:.1}", m_iter_rust.mean_ms())]);
    t.row(&["PJRT tiles".into(), format!("{:.1}", m_iter_pjrt.mean_ms())]);
    t.print();

    // ---- XOR-fold on the accelerator vs rust ------------------------------
    println!("\n## coded-shuffle Encode: XOR fold (r=4, 1M columns)");
    let rcount = 4usize;
    let m = 1 << 20;
    let mut table = vec![0i32; rcount * m];
    let mut s = 1u64;
    for v in table.iter_mut() {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
        *v = (s >> 33) as i32;
    }
    let m_xla = bench.run(|| exec.xor_fold(rcount, &table).unwrap());
    let m_rs = bench.run(|| {
        let mut out = vec![0i32; m];
        for row in 0..rcount {
            let base = row * m;
            for c in 0..m {
                out[c] ^= table[base + c];
            }
        }
        out
    });
    let bytes = (rcount * m * 4) as f64;
    println!(
        "xla xor_fold: {:.1} ms ({:.0} MB/s)   rust xor: {:.2} ms ({:.0} MB/s)",
        m_xla.mean_ms(),
        bytes / m_xla.mean_s / 1e6,
        m_rs.mean_ms(),
        bytes / m_rs.mean_s / 1e6
    );
    println!("\nthe L3 hot path keeps the rust encoder; the Pallas xor_fold artifact");
    println!("demonstrates the Encode stage lowering for accelerator targets.");
    Ok(())
}
