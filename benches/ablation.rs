//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. **Combiners** (paper §VII / [18]): pre-aggregation alone, coding
//!    alone, and both — showing the multiplicative composition.
//! 2. **Degree-interleaved batches** (realization-aware allocation, §VII):
//!    contiguous vs interleaved batch assignment on power-law graphs.
//! 3. **Multicast penalty sensitivity**: how the EC2 overhead parameter
//!    moves the optimal r (the saturation effect of Fig 7).
//! 4. **Segment padding waste**: wire bytes vs paper bits across r.
//!
//! ```sh
//! cargo bench --bench ablation
//! ```

use coded_graph::allocation::interleave::{batch_volumes, degree_interleave_perm};
use coded_graph::allocation::Allocation;
use coded_graph::coordinator::measure_loads;
use coded_graph::experiments::scenarios::{scenario, speedup_over_naive, build_graph};
use coded_graph::graph::er::er;
use coded_graph::graph::powerlaw::{pl, PlParams};
use coded_graph::shuffle::combined::measure_combined_loads;
use coded_graph::shuffle::segments::seg_bytes;
use coded_graph::util::benchkit::Table;
use coded_graph::util::rng::DetRng;
use coded_graph::Vertex;

fn main() {
    combiners();
    interleave();
    multicast_penalty();
    padding();
}

fn combiners() {
    println!("# Ablation 1: combiners x coding (ER n=1200, p=0.3, K=5)");
    let g = er(1200, 0.3, &mut DetRng::seed(11));
    let mut t = Table::new(&[
        "r", "plain uncoded", "+coding", "+combiners", "+both", "total gain",
    ]);
    for r in 2..5 {
        let alloc = Allocation::er_scheme(1200, 5, r);
        let (unc, cod) = measure_loads(&g, &alloc);
        let (unc_c, cod_c) = measure_combined_loads(&g, &alloc);
        t.row(&[
            r.to_string(),
            format!("{unc:.5}"),
            format!("{cod:.5} ({:.1}x)", unc / cod),
            format!("{unc_c:.5} ({:.1}x)", unc / unc_c),
            format!("{cod_c:.5} ({:.1}x)", unc / cod_c),
            format!("{:.1}x", unc / cod_c),
        ]);
    }
    t.print();
    println!("composition: gain(both) ~ gain(coding) x gain(combiners) — [18]'s result\n");
}

fn interleave() {
    println!("# Ablation 2: contiguous vs degree-interleaved batches (PL graphs)");
    let mut t = Table::new(&[
        "n", "r", "vol spread contig", "vol spread interl", "coded L contig", "coded L interl", "saved",
    ]);
    for (n, r) in [(3000usize, 2usize), (3000, 3), (6000, 2)] {
        let k = 5;
        let g = pl(
            n,
            PlParams { gamma: 2.2, max_degree: 100_000, rho_scale: 4.0 },
            &mut DetRng::seed(n as u64),
        );
        let alloc = Allocation::er_scheme(n, k, r);
        let nb = alloc.batches.len();
        let identity: Vec<Vertex> = (0..n as Vertex).collect();
        let spread = |v: &[usize]| {
            let max = *v.iter().max().unwrap() as f64;
            let mean = v.iter().sum::<usize>() as f64 / v.len() as f64;
            max / mean
        };
        let s_id = spread(&batch_volumes(&g, &identity, nb));
        let perm = degree_interleave_perm(&g, nb);
        let s_il = spread(&batch_volumes(&g, &perm, nb));
        let (_, cod_id) = measure_loads(&g, &alloc);
        let g_il = g.relabel(&perm);
        let (_, cod_il) = measure_loads(&g_il, &alloc);
        t.row(&[
            n.to_string(),
            r.to_string(),
            format!("{s_id:.2}"),
            format!("{s_il:.2}"),
            format!("{cod_id:.6}"),
            format!("{cod_il:.6}"),
            format!("{:+.1}%", (1.0 - cod_il / cod_id) * 100.0),
        ]);
    }
    t.print();
    println!("realization-aware placement shaves the per-group max row (E[Q])\n");
}

fn multicast_penalty() {
    println!("# Ablation 3: multicast penalty vs optimal r (Scenario 2 at 1/6 scale)");
    let sc = scenario(2, 6);
    let g = build_graph(&sc, 77);
    let mut t = Table::new(&["penalty", "best r", "speedup vs naive"]);
    for penalty in [0.0, 0.1, 0.15, 0.3, 0.6, 1.0] {
        // patch the testbed's bus model through an env-free path: rerun the
        // scenario sweep with a custom config by reusing run_scenario_on
        // and overriding afterwards is cleaner than plumbing config — the
        // sweep itself reads the default testbed, so emulate via direct calls:
        let rows = {
            use coded_graph::coordinator::{run_rust, EngineConfig, Job, Scheme};
            use coded_graph::mapreduce::PageRank;
            use coded_graph::network::BusConfig;
            let prog = PageRank::default();
            let mut rows = Vec::new();
            for r in 1..=sc.r_max.min(sc.k) {
                let (alloc, scheme) = if r == 1 {
                    (Allocation::single(g.n(), sc.k), Scheme::Uncoded)
                } else {
                    (Allocation::er_scheme(g.n(), sc.k, r), Scheme::Coded)
                };
                let cfg = EngineConfig {
                    scheme,
                    bus: BusConfig { multicast_penalty: penalty, ..BusConfig::default() },
                    ..Default::default()
                };
                let job = Job { graph: &g, alloc: &alloc, program: &prog };
                let report = run_rust(&job, &cfg, 1);
                let m = &report.iterations[0];
                rows.push(coded_graph::experiments::scenarios::ScenarioRow {
                    r,
                    scheme,
                    times: m.times,
                    total_s: m.times.total(),
                    load: m.shuffle.normalized(g.n()),
                    wall_s: m.wall_s,
                });
            }
            rows
        };
        let (best_r, speedup) = speedup_over_naive(&rows);
        t.row(&[
            format!("{penalty:.2}"),
            best_r.to_string(),
            format!("{:.1}%", speedup * 100.0),
        ]);
    }
    t.print();
    println!("higher multicast overhead pushes the optimum toward smaller r — the\npaper's saturation effect (§VI-B, last bullet)\n");
}

fn padding() {
    println!("# Ablation 4: segment padding waste (wire bytes vs paper bits)");
    let g = er(800, 0.1, &mut DetRng::seed(21));
    let mut t = Table::new(&["r", "seg bytes", "paper bits/col", "wire bits/col", "waste"]);
    for r in 1..8 {
        let sb = seg_bytes(r);
        let paper = 64.0 / r as f64;
        let wire = (sb * 8) as f64;
        t.row(&[
            r.to_string(),
            sb.to_string(),
            format!("{paper:.1}"),
            format!("{wire:.0}"),
            format!("{:+.0}%", (wire / paper - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("r in {{1,2,4,8}} pads nothing; odd r pays <= 50% on the wire (T = 64)\n");
    let _ = g;
}
