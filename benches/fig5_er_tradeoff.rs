//! Bench: regenerate **paper Fig 5** — average normalized communication
//! load vs computation load r for ER(n=300, p=0.1), K=5: coded scheme,
//! uncoded scheme, and the proposed lower bound, averaged over graph
//! realizations. The paper's reading: the coded curve hugs the lower
//! bound (small optimality gap) and sits ≈ r below the uncoded curve.
//!
//! ```sh
//! cargo bench --bench fig5_er_tradeoff
//! ```

use coded_graph::experiments::fig5::{run, Fig5Params};
use coded_graph::util::benchkit::{Bench, Table};

fn main() {
    let params = Fig5Params::default(); // the paper's n=300, p=0.1, K=5
    println!(
        "# Fig 5 reproduction: ER(n={}, p={}), K={}, {} graph draws per point\n",
        params.n, params.p, params.k, params.trials
    );
    let (rows, secs) = Bench::once(|| run(params));
    let mut t = Table::new(&[
        "r",
        "uncoded L (meas)",
        "coded L (meas)",
        "lower bound",
        "finite-n pred",
        "gain",
        "gap vs bound",
    ]);
    for row in &rows {
        t.row(&[
            row.r.to_string(),
            format!("{:.5} ±{:.5}", row.uncoded.mean, row.uncoded.ci95()),
            format!("{:.5} ±{:.5}", row.coded.mean, row.coded.ci95()),
            format!("{:.5}", row.lower_bound),
            format!("{:.5}", row.coded_finite_pred),
            format!("{:.2}x", row.gain()),
            format!("{:+.1}%", (row.coded.mean / row.lower_bound - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("\n({} draws x {} r-values in {:.2}s)", params.trials, rows.len(), secs);
    println!("paper shape check: gain -> r, coded within ~15% of the bound at n=300");
}
