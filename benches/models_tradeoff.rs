//! Bench: the **Theorem 1–4 trade-off tables** — measured coded/uncoded
//! loads vs the paper's closed forms for all four random-graph models
//! (ER / random bi-partite / stochastic block / power law), plus a
//! convergence sweep in n for ER showing the finite-n optimality gap
//! closing (the "small optimality gap" claim under Fig 5).
//!
//! ```sh
//! cargo bench --bench models_tradeoff
//! ```

use coded_graph::allocation::Allocation;
use coded_graph::analysis::theory;
use coded_graph::coordinator::measure_loads;
use coded_graph::experiments::models::{sweep, Model, SweepParams};
use coded_graph::graph::er::er;
use coded_graph::util::benchkit::{Bench, Table};
use coded_graph::util::rng::DetRng;

fn main() {
    let params = SweepParams { n: 600, k: 6, trials: 10, ..Default::default() };
    println!(
        "# Theorems 1-4: measured loads vs closed forms (n={}, K={}, {} draws)",
        params.n, params.k, params.trials
    );
    for model in [Model::Er, Model::Rb, Model::Sbm, Model::Pl] {
        println!("\n## {model}");
        let (rows, secs) = Bench::once(|| sweep(model, params));
        let mut t = Table::new(&["r", "uncoded", "coded", "gain", "thm upper", "thm lower"]);
        for row in &rows {
            t.row(&[
                row.r.to_string(),
                format!("{:.5}", row.uncoded.mean),
                format!("{:.5}", row.coded.mean),
                format!("{:.2}x", row.gain()),
                if row.predicted_upper.is_nan() { "-".into() } else { format!("{:.5}", row.predicted_upper) },
                if row.predicted_lower.is_nan() { "-".into() } else { format!("{:.5}", row.predicted_lower) },
            ]);
        }
        t.print();
        println!("[{secs:.1}s]");
    }

    // ---- ER optimality-gap convergence (Remark 4 / Fig 5 inset) ----------
    println!("\n## ER optimality gap vs n (r=2, K=5, p=0.1)");
    let (p, k, r) = (0.1, 5usize, 2usize);
    let mut t = Table::new(&["n", "coded L", "lower bound", "gap"]);
    for n in [100usize, 200, 400, 800, 1600] {
        let trials = 6;
        let mut acc = 0.0;
        for s in 0..trials {
            let g = er(n, p, &mut DetRng::seed(1000 + s));
            let alloc = Allocation::er_scheme(n, k, r);
            acc += measure_loads(&g, &alloc).1;
        }
        let coded = acc / trials as f64;
        let bound = theory::lower_bound_er(p, r as f64, k);
        t.row(&[
            n.to_string(),
            format!("{coded:.5}"),
            format!("{bound:.5}"),
            format!("{:+.1}%", (coded / bound - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("gap shrinks like O(1/sqrt(n p g)) — Lemma 1's second-order term.");

    let _ = Bench::default();
}
