//! Bench: Shuffle hot-path microbenchmarks — the §Perf workhorse.
//!
//! Measures, per computation load r:
//!   * group-plan construction (pre-processing, O(m)) into the flat arena,
//!   * coded Encode throughput (arena kernel, bytes/s),
//!   * coded Decode throughput (arena kernel, bytes/s),
//!   * uncoded transfer planning,
//! on a dense mid-size ER graph, then full coded engine iterations
//! (Map → Encode → Shuffle → Decode → Reduce → write-back) on a
//! ~200k-edge ER graph with a warm [`EngineScratch`] — the steady-state
//! iterations are allocation-free (see the `zero_alloc` test) — on both
//! the serial and the rayon-parallel path.
//!
//! ```sh
//! cargo bench --bench shuffle_micro             # full configuration
//! cargo bench --bench shuffle_micro -- --smoke  # seconds-scale CI smoke
//! ```

use coded_graph::allocation::Allocation;
use coded_graph::coordinator::{
    prepare, run_iteration_scratch, Backend, EngineConfig, EngineScratch, Job, Scheme,
};
use coded_graph::graph::er::er;
use coded_graph::mapreduce::{PageRank, VertexProgram};
use coded_graph::shuffle::coded::{encode_group_into, eval_group_values};
use coded_graph::shuffle::decoder::decode_group_into;
use coded_graph::shuffle::plan::build_group_plans;
use coded_graph::shuffle::segments::seg_bytes;
use coded_graph::shuffle::uncoded::plan_uncoded;
use coded_graph::util::benchkit::{Bench, Table};
use coded_graph::util::rng::DetRng;
use coded_graph::Vertex;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    micro(smoke);
    iteration_throughput(smoke);
}

/// Arena-kernel microbenchmarks: plan / encode / decode / uncoded-plan.
fn micro(smoke: bool) {
    let (n, p, k) = if smoke { (600usize, 0.1f64, 5usize) } else { (3000, 0.1, 6) };
    let g = er(n, p, &mut DetRng::seed(123));
    println!("# Shuffle micro-benchmarks: ER(n={n}, p={p}), K={k}, m={}\n", g.m());
    let prog = PageRank::default();
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let bench = if smoke { Bench::new(1, 2) } else { Bench::new(1, 5) };

    let mut t = Table::new(&[
        "r", "plan (ms)", "ivs", "encode (ms)", "enc MB/s", "decode (ms)", "dec MB/s", "uncoded plan (ms)",
    ]);
    for r in 2..k {
        let alloc = Allocation::er_scheme(n, k, r);
        let m_plan = bench.run(|| build_group_plans(&g, &alloc));
        let plan = build_group_plans(&g, &alloc);
        let total_ivs = plan.total_ivs();
        let value = |i: Vertex, j: Vertex| prog.map(i, j, state[j as usize], &g).to_bits();

        // warm arenas shared by the encode and decode measurements
        let mut vals = vec![0u64; plan.total_ivs()];
        let mut cols = vec![0u64; plan.total_cols()];
        let mut bits = vec![0u64; plan.total_ivs()];
        for gi in 0..plan.num_groups() {
            let vr = plan.pair_range(gi);
            eval_group_values(plan.group(gi), &value, &mut vals[vr]);
        }

        // encode: all groups, all senders, straight into the column arena
        let m_enc = bench.run(|| {
            for gi in 0..plan.num_groups() {
                let vr = plan.pair_range(gi);
                let cr = plan.col_range(gi);
                encode_group_into(
                    plan.group(gi),
                    &vals[vr],
                    r,
                    plan.sender_cols(gi),
                    &mut cols[cr],
                );
            }
            cols.last().copied()
        });
        // table bytes XORed per full encode: every row appears in r tables
        let enc_bytes = total_ivs * seg_bytes(r) * r;

        // decode: every member of every group, into the bits arena
        let m_dec = bench.run(|| {
            for gi in 0..plan.num_groups() {
                let vr = plan.pair_range(gi);
                let cr = plan.col_range(gi);
                decode_group_into(
                    plan.group(gi),
                    &vals[vr.clone()],
                    &cols[cr],
                    plan.sender_cols(gi),
                    r,
                    &mut bits[vr],
                );
            }
            bits.last().copied()
        });
        let dec_bytes = total_ivs * seg_bytes(r) * r; // segments recovered

        let m_unc = bench.run(|| plan_uncoded(&g, &alloc));

        t.row(&[
            r.to_string(),
            format!("{:.2}", m_plan.mean_ms()),
            total_ivs.to_string(),
            format!("{:.2}", m_enc.mean_ms()),
            format!("{:.0}", enc_bytes as f64 / m_enc.mean_s / 1e6),
            format!("{:.2}", m_dec.mean_ms()),
            format!("{:.0}", dec_bytes as f64 / m_dec.mean_s / 1e6),
            format!("{:.2}", m_unc.mean_ms()),
        ]);
    }
    t.print();
    println!("\nnote: decode re-derives r-1 foreign segments per own segment, so its");
    println!("byte throughput is inherently ~1/r of encode's on the same table.\n");
}

/// Full coded engine iterations on a ~200k-edge ER graph: the headline
/// steady-state throughput number (warm scratch, zero allocation).
fn iteration_throughput(smoke: bool) {
    let (n, p, k) = if smoke { (500usize, 0.08f64, 5usize) } else { (2000, 0.1, 6) };
    let g = er(n, p, &mut DetRng::seed(321));
    println!("# Coded engine iterations: ER(n={n}, p={p}), K={k}, m={} (~200k edges full size)\n", g.m());
    let prog = PageRank::default();
    let bench = if smoke { Bench::new(1, 2) } else { Bench::new(2, 5) };

    let mut t = Table::new(&[
        "r", "serial iter (ms)", "parallel iter (ms)", "iters/s (par)", "norm load",
    ]);
    for r in 2..=(k - 2) {
        let alloc = Allocation::er_scheme(n, k, r);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let prep = prepare(&job, Scheme::Coded);
        let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
        let mut next = vec![0.0f64; n];
        let mut scratch = EngineScratch::new();
        let mut load = 0.0;

        let serial_cfg =
            EngineConfig { scheme: Scheme::Coded, parallel: false, ..Default::default() };
        let m_serial = bench.run(|| {
            let m = run_iteration_scratch(
                &job, &prep, &state, &serial_cfg, &mut Backend::Rust, &mut scratch, &mut next,
            );
            load = m.shuffle.normalized(n);
        });

        let par_cfg = EngineConfig { scheme: Scheme::Coded, parallel: true, ..Default::default() };
        let m_par = bench.run(|| {
            run_iteration_scratch(
                &job, &prep, &state, &par_cfg, &mut Backend::Rust, &mut scratch, &mut next,
            );
        });

        t.row(&[
            r.to_string(),
            format!("{:.2}", m_serial.mean_ms()),
            format!("{:.2}", m_par.mean_ms()),
            format!("{:.0}", 1.0 / m_par.mean_s),
            format!("{:.5}", load),
        ]);
    }
    t.print();
    println!("\nserial and parallel paths are bit-identical (asserted in the test suite);");
    println!("steady-state iterations perform zero heap allocation (tests/zero_alloc.rs).");
}
