//! Bench: Shuffle hot-path microbenchmarks — the §Perf workhorse.
//!
//! Measures, per computation load r:
//!   * group-plan construction (pre-processing, O(m)) into the flat arena,
//!   * coded Encode throughput (the production single-sender kernels —
//!     `eval_rows_except` + `encode_sender_into`, exactly what every
//!     driver's worker core runs — bytes/s),
//!   * coded Decode throughput (`decode_sender_into`, per (member,
//!     sender), bytes/s),
//!   * uncoded transfer planning,
//! on a dense mid-size ER graph; then sharded vs full prepare at
//! (K=10, r=3) scale (the per-worker `prepare_worker` path every worker
//! core runs — expected ≥2× faster than the global `prepare`); then
//! full coded engine iterations (Map → Encode → Shuffle → Decode →
//! Reduce → write-back) on a ~200k-edge ER graph with a warm
//! [`EngineScratch`] on both the serial and the rayon-parallel path;
//! then the `core_parity` section: per-iteration wall time of the
//! unified `WorkerCore` + `DirectFabric` engine at the ISSUE-5 pin
//! (K=10, r=3), the record to diff against pre-refactor `iteration`
//! numbers for perf-neutrality; then the `observer_overhead` section:
//! the same serial iteration with the ISSUE-7 flight recorder on (the
//! default) vs off, pinning the tracing cost under its 5% budget; then
//! the TCP batched wire path
//! (per-frame writes vs one buffered flush per destination); and
//! finally the `recovery` section: degraded-mode cost at (K=10, r=3) —
//! recovery latency, re-planned groups, and wire-byte inflation as the
//! in-process cluster survives 0, 1, and 2 injected worker deaths, plus
//! the PR 9 records: the adopter-kill cascade (two chained recovery
//! epochs) and the checkpoint write / parse / warm-resume costs.
//!
//! ```sh
//! cargo bench --bench shuffle_micro                   # full configuration
//! cargo bench --bench shuffle_micro -- --smoke        # seconds-scale CI smoke
//! cargo bench --bench shuffle_micro -- --smoke --json BENCH_shuffle_micro.json
//! ```
//!
//! `--json PATH` additionally writes every measurement as one JSON
//! record (`{"suite": "shuffle_micro", "records": [...]}`) — the perf
//! trajectory CI archives per commit.

use coded_graph::allocation::Allocation;
use coded_graph::coordinator::{
    mesh_ring_capacities, prepare, prepare_worker, run_cluster_net, run_iteration_scratch,
    try_run_cluster_on, try_run_cluster_on_with, AllocKind, Backend, Checkpoint, EngineConfig,
    EngineScratch, FabricKind, FailWorker, GraphKind, GraphSpec, Job, JobReport, JobSpec,
    ProgramSpec, RunOpts, Scheme,
};
use coded_graph::graph::er::er;
use coded_graph::mapreduce::{PageRank, VertexProgram};
use coded_graph::shuffle::coded::{encode_sender_into, eval_rows_except};
use coded_graph::shuffle::decoder::decode_sender_into;
use coded_graph::shuffle::plan::build_group_plans;
use coded_graph::shuffle::segments::seg_bytes;
use coded_graph::shuffle::uncoded::plan_uncoded;
use coded_graph::transport::{frame, TcpNet, Transport, TransportKind};
use coded_graph::util::benchkit::{Bench, BenchJson, Table};
use coded_graph::util::json::Json;
use coded_graph::util::rng::DetRng;
use coded_graph::Vertex;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut report = BenchJson::new("shuffle_micro");
    micro(smoke, &mut report);
    prepare_sharded(smoke, &mut report);
    iteration_throughput(smoke, &mut report);
    core_parity(smoke, &mut report);
    observer_overhead(smoke, &mut report);
    tcp_batching(smoke, &mut report);
    overlap(smoke, &mut report);
    recovery(smoke, &mut report);
    if let Some(path) = json_path {
        report.write(&path).expect("write bench json");
        println!("\nwrote {path}");
    }
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Arena-kernel microbenchmarks: plan / encode / decode / uncoded-plan.
fn micro(smoke: bool, report: &mut BenchJson) {
    let (n, p, k) = if smoke { (600usize, 0.1f64, 5usize) } else { (3000, 0.1, 6) };
    let g = er(n, p, &mut DetRng::seed(123));
    println!("# Shuffle micro-benchmarks: ER(n={n}, p={p}), K={k}, m={}\n", g.m());
    let prog = PageRank::default();
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let bench = if smoke { Bench::new(1, 2) } else { Bench::new(1, 5) };

    let mut t = Table::new(&[
        "r", "plan (ms)", "ivs", "encode (ms)", "enc MB/s", "decode (ms)", "dec MB/s", "uncoded plan (ms)",
    ]);
    for r in 2..k {
        let alloc = Allocation::er_scheme(n, k, r);
        let m_plan = bench.run(|| build_group_plans(&g, &alloc));
        let plan = build_group_plans(&g, &alloc);
        let total_ivs = plan.total_ivs();
        let value = |i: Vertex, j: Vertex| prog.map(i, j, state[j as usize], &g).to_bits();

        // warm arenas shared by the encode and decode measurements; the
        // per-group values are evaluated inline (every row) so decode can
        // cancel with them — the worker core keeps the equivalent `gvals`
        // arena warm across an iteration
        let mut vals = vec![0u64; plan.total_ivs()];
        let mut cols = vec![0u64; plan.total_cols()];
        let mut bits = vec![0u64; plan.groups().map(|gp| gp.max_row_len()).max().unwrap_or(0)];
        for gi in 0..plan.num_groups() {
            let vr = plan.pair_range(gi);
            for (slot, &(i, j)) in vals[vr].iter_mut().zip(plan.group(gi).group_pairs()) {
                *slot = value(i, j);
            }
        }

        // encode: every (group, sender) through the production kernels —
        // evaluate the foreign rows, XOR the sender's columns into the
        // sender-major arena (what one iteration of send staging costs)
        let mut evals = vec![0u64; plan.groups().map(|gp| gp.total_ivs()).max().unwrap_or(0)];
        let m_enc = bench.run(|| {
            for gi in 0..plan.num_groups() {
                let group = plan.group(gi);
                let nv = group.total_ivs();
                let cr = plan.col_range(gi);
                let gcols = &mut cols[cr];
                let mut cbase = 0usize;
                for (s_idx, &q) in plan.sender_cols(gi).iter().enumerate() {
                    let q = q as usize;
                    eval_rows_except(group, s_idx, &value, &mut evals[..nv]);
                    encode_sender_into(group, s_idx, &evals[..nv], r, &mut gcols[cbase..cbase + q]);
                    cbase += q;
                }
            }
            cols.last().copied()
        });
        // table bytes XORed per full encode: every row appears in r tables
        let enc_bytes = total_ivs * seg_bytes(r) * r;

        // decode: every (member, sender) pair through the production
        // kernel, reassembling each member's row from the column arena
        let m_dec = bench.run(|| {
            let mut check = 0u64;
            for gi in 0..plan.num_groups() {
                let group = plan.group(gi);
                let vr = plan.pair_range(gi);
                let gvals = &vals[vr];
                let cr = plan.col_range(gi);
                let gcols = &cols[cr];
                for m_idx in 0..group.members() {
                    let my_len = group.row_len(m_idx);
                    if my_len == 0 {
                        continue;
                    }
                    let out = &mut bits[..my_len];
                    out.fill(0);
                    let mut cbase = 0usize;
                    for (s_idx, &q) in plan.sender_cols(gi).iter().enumerate() {
                        let q = q as usize;
                        if s_idx != m_idx {
                            decode_sender_into(
                                group,
                                m_idx,
                                s_idx,
                                &gcols[cbase..cbase + my_len],
                                gvals,
                                r,
                                out,
                            );
                        }
                        cbase += q;
                    }
                    check = check.wrapping_add(out[my_len - 1]);
                }
            }
            check
        });
        let dec_bytes = total_ivs * seg_bytes(r) * r; // segments recovered

        let m_unc = bench.run(|| plan_uncoded(&g, &alloc));

        let params = |extra: &[(&'static str, Json)]| -> Vec<(&'static str, Json)> {
            let mut fields = vec![
                ("n", num(n as f64)),
                ("p", num(p)),
                ("k", num(k as f64)),
                ("r", num(r as f64)),
            ];
            fields.extend_from_slice(extra);
            fields
        };
        report.record(
            "plan",
            &params(&[("mean_s", num(m_plan.mean_s)), ("ivs", num(total_ivs as f64))]),
        );
        report.record(
            "encode",
            &params(&[("mean_s", num(m_enc.mean_s)), ("bytes", num(enc_bytes as f64))]),
        );
        report.record(
            "decode",
            &params(&[("mean_s", num(m_dec.mean_s)), ("bytes", num(dec_bytes as f64))]),
        );
        report.record("uncoded_plan", &params(&[("mean_s", num(m_unc.mean_s))]));

        t.row(&[
            r.to_string(),
            format!("{:.2}", m_plan.mean_ms()),
            total_ivs.to_string(),
            format!("{:.2}", m_enc.mean_ms()),
            format!("{:.0}", enc_bytes as f64 / m_enc.mean_s / 1e6),
            format!("{:.2}", m_dec.mean_ms()),
            format!("{:.0}", dec_bytes as f64 / m_dec.mean_s / 1e6),
            format!("{:.2}", m_unc.mean_ms()),
        ]);
    }
    t.print();
    println!("\nnote: decode re-derives r-1 foreign segments per own segment, so its");
    println!("byte throughput is inherently ~1/r of encode's on the same table.\n");
}

/// Sharded vs full prepare at (K=10, r=3) scale: what a cluster worker
/// runs at startup. `prepare_worker` only materializes the `(r+1)/K`
/// fraction of groups the worker is a member of and skips the global
/// tallies, so it should beat the full `prepare` by well over 2×.
fn prepare_sharded(smoke: bool, report: &mut BenchJson) {
    let (n, p) = if smoke { (1200usize, 0.06f64) } else { (4000, 0.05) };
    let (k, r) = (10usize, 3usize);
    let g = er(n, p, &mut DetRng::seed(777));
    let alloc = Allocation::er_scheme(n, k, r);
    let prog = PageRank::default();
    let job = Job { graph: &g, alloc: &alloc, program: &prog };
    let bench = if smoke { Bench::new(1, 3) } else { Bench::new(2, 5) };

    let m_full = bench.run(|| prepare(&job, Scheme::Coded));
    let m_shard = bench.run(|| prepare_worker(&job, Scheme::Coded, 0));
    let full_ivs = prepare(&job, Scheme::Coded).plan.total_ivs();
    let shard_ivs = prepare_worker(&job, Scheme::Coded, 0).plan.total_ivs();
    let speedup = m_full.mean_s / m_shard.mean_s;

    println!("# Sharded prepare: ER(n={n}, p={p}), K={k}, r={r}, m={}\n", g.m());
    println!(
        "full prepare: {:.2} ms ({} ivs)   prepare_worker(0): {:.2} ms ({} ivs)   speedup {speedup:.1}x",
        m_full.mean_ms(),
        full_ivs,
        m_shard.mean_ms(),
        shard_ivs,
    );
    println!(
        "shard fraction: {:.3} of the global pair arena ((r+1)/K = {:.3})\n",
        shard_ivs as f64 / full_ivs as f64,
        (r + 1) as f64 / k as f64
    );
    report.record(
        "prepare_full",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("mean_s", num(m_full.mean_s)),
            ("ivs", num(full_ivs as f64)),
        ],
    );
    report.record(
        "prepare_worker",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("mean_s", num(m_shard.mean_s)),
            ("ivs", num(shard_ivs as f64)),
            ("speedup_vs_full", num(speedup)),
        ],
    );
}

/// Full coded engine iterations on a ~200k-edge ER graph: the headline
/// steady-state throughput number (warm scratch, zero allocation).
fn iteration_throughput(smoke: bool, report: &mut BenchJson) {
    let (n, p, k) = if smoke { (500usize, 0.08f64, 5usize) } else { (2000, 0.1, 6) };
    let g = er(n, p, &mut DetRng::seed(321));
    println!("# Coded engine iterations: ER(n={n}, p={p}), K={k}, m={} (~200k edges full size)\n", g.m());
    let prog = PageRank::default();
    let bench = if smoke { Bench::new(1, 2) } else { Bench::new(2, 5) };

    let mut t = Table::new(&[
        "r", "serial iter (ms)", "parallel iter (ms)", "iters/s (par)", "norm load",
    ]);
    for r in 2..=(k - 2) {
        let alloc = Allocation::er_scheme(n, k, r);
        let job = Job { graph: &g, alloc: &alloc, program: &prog };
        let prep = prepare(&job, Scheme::Coded);
        let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
        let mut next = vec![0.0f64; n];
        let mut scratch = EngineScratch::new();
        let mut load = 0.0;

        let serial_cfg =
            EngineConfig { scheme: Scheme::Coded, parallel: false, ..Default::default() };
        let m_serial = bench.run(|| {
            let m = run_iteration_scratch(
                &job, &prep, &state, &serial_cfg, &mut Backend::Rust, &mut scratch, &mut next,
            );
            load = m.shuffle.normalized(n);
        });

        let par_cfg = EngineConfig { scheme: Scheme::Coded, parallel: true, ..Default::default() };
        let m_par = bench.run(|| {
            run_iteration_scratch(
                &job, &prep, &state, &par_cfg, &mut Backend::Rust, &mut scratch, &mut next,
            );
        });

        report.record(
            "iteration",
            &[
                ("n", num(n as f64)),
                ("p", num(p)),
                ("k", num(k as f64)),
                ("r", num(r as f64)),
                ("serial_mean_s", num(m_serial.mean_s)),
                ("parallel_mean_s", num(m_par.mean_s)),
                ("norm_load", num(load)),
            ],
        );

        t.row(&[
            r.to_string(),
            format!("{:.2}", m_serial.mean_ms()),
            format!("{:.2}", m_par.mean_ms()),
            format!("{:.0}", 1.0 / m_par.mean_s),
            format!("{:.5}", load),
        ]);
    }
    t.print();
    println!("\nserial and parallel paths are bit-identical (asserted in the test suite);");
    println!("steady-state iterations perform zero heap allocation (tests/zero_alloc.rs).\n");
}

/// Core parity at the ISSUE-5 pin (K=10, r=3): per-iteration wall time
/// of the unified engine — `K` `WorkerCore`s exchanging serialized
/// frames over the in-memory `DirectFabric` — on serial and parallel
/// paths. Diff the `core_parity` records in `BENCH_shuffle_micro.json`
/// against the pre-refactor full-iteration numbers to confirm the
/// one-worker-core refactor is perf-neutral-or-better.
fn core_parity(smoke: bool, report: &mut BenchJson) {
    let (n, p) = if smoke { (800usize, 0.05f64) } else { (3000, 0.05) };
    let (k, r) = (10usize, 3usize);
    let g = er(n, p, &mut DetRng::seed(999));
    let prog = PageRank::default();
    let alloc = Allocation::er_scheme(n, k, r);
    let job = Job { graph: &g, alloc: &alloc, program: &prog };
    let prep = prepare(&job, Scheme::Coded);
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let mut next = vec![0.0f64; n];
    let mut scratch = EngineScratch::new();
    let bench = if smoke { Bench::new(1, 3) } else { Bench::new(2, 6) };
    let mut load = 0.0;

    let serial_cfg = EngineConfig { scheme: Scheme::Coded, parallel: false, ..Default::default() };
    let m_serial = bench.run(|| {
        let m = run_iteration_scratch(
            &job, &prep, &state, &serial_cfg, &mut Backend::Rust, &mut scratch, &mut next,
        );
        load = m.shuffle.normalized(n);
    });
    let par_cfg = EngineConfig { scheme: Scheme::Coded, parallel: true, ..Default::default() };
    let m_par = bench.run(|| {
        run_iteration_scratch(
            &job, &prep, &state, &par_cfg, &mut Backend::Rust, &mut scratch, &mut next,
        );
    });

    println!("# Core parity: WorkerCore + DirectFabric engine, ER(n={n}, p={p}), K={k}, r={r}\n");
    println!(
        "serial iter: {:.2} ms   parallel iter: {:.2} ms   norm load {:.5}",
        m_serial.mean_ms(),
        m_par.mean_ms(),
        load
    );
    println!("(diff against the pre-refactor `iteration` records to confirm perf parity)\n");
    report.record(
        "core_parity",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("serial_mean_s", num(m_serial.mean_s)),
            ("parallel_mean_s", num(m_par.mean_s)),
            ("norm_load", num(load)),
        ],
    );
}

/// Observer effect at the ISSUE-7 pin (K=10, r=3): the same serial
/// engine iteration with the flight recorder on (the default) vs off.
/// Recording is a fixed-size slot write into a preallocated ring plus a
/// handful of clock reads per phase, all gated on one branch when off —
/// `make bench-smoke` pins the measured overhead under the 5% budget.
fn observer_overhead(smoke: bool, report: &mut BenchJson) {
    let (n, p) = if smoke { (800usize, 0.05f64) } else { (3000, 0.05) };
    let (k, r) = (10usize, 3usize);
    let g = er(n, p, &mut DetRng::seed(2718));
    let prog = PageRank::default();
    let alloc = Allocation::er_scheme(n, k, r);
    let job = Job { graph: &g, alloc: &alloc, program: &prog };
    let prep = prepare(&job, Scheme::Coded);
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let mut next = vec![0.0f64; n];
    let mut scratch = EngineScratch::new();
    let bench = if smoke { Bench::new(1, 5) } else { Bench::new(2, 8) };

    let on_cfg = EngineConfig { scheme: Scheme::Coded, parallel: false, ..Default::default() };
    let off_cfg = EngineConfig { trace: false, ..on_cfg };
    // warm both paths once so neither measurement pays first-touch costs
    run_iteration_scratch(
        &job, &prep, &state, &on_cfg, &mut Backend::Rust, &mut scratch, &mut next,
    );
    run_iteration_scratch(
        &job, &prep, &state, &off_cfg, &mut Backend::Rust, &mut scratch, &mut next,
    );

    let m_off = bench.run(|| {
        run_iteration_scratch(
            &job, &prep, &state, &off_cfg, &mut Backend::Rust, &mut scratch, &mut next,
        );
    });
    let m_on = bench.run(|| {
        run_iteration_scratch(
            &job, &prep, &state, &on_cfg, &mut Backend::Rust, &mut scratch, &mut next,
        );
    });
    let overhead = m_on.mean_s / m_off.mean_s - 1.0;

    println!("# Observer overhead: flight recorder on vs off, ER(n={n}, p={p}), K={k}, r={r}\n");
    println!(
        "untraced iter: {:.3} ms   traced iter: {:.3} ms   overhead {:+.2}%",
        m_off.mean_ms(),
        m_on.mean_ms(),
        overhead * 100.0
    );
    println!("(budget: under 5%; asserted by `make bench-smoke`)\n");
    report.record(
        "observer_overhead",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("traced_mean_s", num(m_on.mean_s)),
            ("untraced_mean_s", num(m_off.mean_s)),
            ("overhead", num(overhead)),
        ],
    );
}

/// Degraded-mode recovery cost at the ISSUE-6 pin (K=10, r=3): run the
/// in-process cluster with 0, 1, and 2 injected worker deaths (the full
/// `r − 1` tolerance) and record what surviving them cost — leader
/// re-plan latency, re-planned groups/transfers, straggler skips, and
/// the wire-byte inflation over the no-failure model. The failure-free
/// row doubles as the regression pin: its inflation must be exactly 0.
///
/// PR 9 adds two kinds of record on top: `recovery_cascade` (the second
/// kill lands on the adopter elected after the first, so the two-epoch
/// re-adoption chain is what's being timed — diff against the plain
/// `failures=1` row for the cascade's marginal cost) and
/// `checkpoint_resume` (serialize/parse cost of the committed-state
/// checkpoint file plus the wall time of a warm-started resume run that
/// must land bit-identical to the uninterrupted job).
fn recovery(smoke: bool, report: &mut BenchJson) {
    let (n, p) = if smoke { (600usize, 0.06f64) } else { (2000, 0.05) };
    let (k, r) = (10usize, 3usize);
    let iters = 4usize;
    let g = er(n, p, &mut DetRng::seed(4242));
    let prog = PageRank::default();
    let alloc = Allocation::er_scheme(n, k, r);
    let job = Job { graph: &g, alloc: &alloc, program: &prog };

    println!("# Degraded-mode recovery: ER(n={n}, p={p}), K={k}, r={r}, {iters} iters, m={}\n", g.m());
    let mut t = Table::new(&[
        "failures", "recovered", "recovery (ms)", "load inflation", "extra KiB", "wall (ms)",
    ]);
    for f in 0..=2usize {
        let mut cfg = EngineConfig { scheme: Scheme::Coded, ..Default::default() };
        if f >= 1 {
            cfg.fail_workers[0] = Some(FailWorker { worker: 3, at_iter: 1 });
        }
        if f >= 2 {
            cfg.fail_workers[1] = Some(FailWorker { worker: 7, at_iter: 2 });
        }
        let t0 = std::time::Instant::now();
        let rep = try_run_cluster_on(&job, &cfg, iters, TransportKind::InProc)
            .expect("within the r-1 tolerance");
        let wall_s = t0.elapsed().as_secs_f64();
        assert_eq!(rep.recovery.failures, f, "every injected death must be recovered");
        let modeled: usize =
            rep.iterations.iter().map(|m| m.shuffle.wire_bytes_with_headers()).sum();
        let extra_bytes = rep.recovery.load_inflation * modeled as f64;

        report.record(
            "recovery",
            &[
                ("n", num(n as f64)),
                ("p", num(p)),
                ("k", num(k as f64)),
                ("r", num(r as f64)),
                ("iters", num(iters as f64)),
                ("failures", num(f as f64)),
                ("recovered_groups", num(rep.recovery.recovered_groups as f64)),
                ("recovery_ms", num(rep.recovery.recovery_ms)),
                ("load_inflation", num(rep.recovery.load_inflation)),
                ("extra_bytes", num(extra_bytes)),
                ("skipped_frames", num(rep.recovery.skipped_frames as f64)),
                ("wall_s", num(wall_s)),
            ],
        );
        t.row(&[
            f.to_string(),
            rep.recovery.recovered_groups.to_string(),
            format!("{:.3}", rep.recovery.recovery_ms),
            format!("{:.4}", rep.recovery.load_inflation),
            format!("{:.1}", extra_bytes / 1024.0),
            format!("{:.1}", wall_s * 1e3),
        ]);
    }
    // the cascade row: worker 3 dies at iteration 1, and the second kill
    // lands on worker 0 — the lowest survivor, i.e. exactly the adopter
    // the leader elected at epoch 1 — forcing the two-epoch re-adoption
    let mut cfg = EngineConfig { scheme: Scheme::Coded, ..Default::default() };
    cfg.fail_workers[0] = Some(FailWorker { worker: 3, at_iter: 1 });
    cfg.fail_workers[1] = Some(FailWorker { worker: 0, at_iter: 2 });
    let t0 = std::time::Instant::now();
    let rep = try_run_cluster_on(&job, &cfg, iters, TransportKind::InProc)
        .expect("an adopter kill cascades, it does not abort");
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(rep.recovery.failures, 2, "both deaths recovered across two epochs");
    let modeled: usize = rep.iterations.iter().map(|m| m.shuffle.wire_bytes_with_headers()).sum();
    let extra_bytes = rep.recovery.load_inflation * modeled as f64;
    report.record(
        "recovery_cascade",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("iters", num(iters as f64)),
            ("failures", num(2.0)),
            ("recovered_groups", num(rep.recovery.recovered_groups as f64)),
            ("recovery_ms", num(rep.recovery.recovery_ms)),
            ("load_inflation", num(rep.recovery.load_inflation)),
            ("extra_bytes", num(extra_bytes)),
            ("skipped_frames", num(rep.recovery.skipped_frames as f64)),
            ("wall_s", num(wall_s)),
        ],
    );
    t.row(&[
        "2 (adopter)".into(),
        rep.recovery.recovered_groups.to_string(),
        format!("{:.3}", rep.recovery.recovery_ms),
        format!("{:.4}", rep.recovery.load_inflation),
        format!("{:.1}", extra_bytes / 1024.0),
        format!("{:.1}", wall_s * 1e3),
    ]);
    t.print();
    println!("\nfailures are injected at iteration 1 (worker 3) and 2 (worker 7); the");
    println!("cascade row re-kills the elected adopter (worker 0) instead; the final");
    println!("state stays bit-identical to the no-failure run (tests/fault_matrix.rs).\n");

    checkpoint_resume(smoke, report, &job, n, p, k, r, iters);
}

/// Checkpoint write/read cost plus the wall time of a resume run
/// warm-started from the mid-job committed state (PR 9). The resume must
/// finish on exactly the bits the uninterrupted run produced.
#[allow(clippy::too_many_arguments)]
fn checkpoint_resume(
    smoke: bool,
    report: &mut BenchJson,
    job: &Job<'_>,
    n: usize,
    p: f64,
    k: usize,
    r: usize,
    iters: usize,
) {
    let cfg = EngineConfig { scheme: Scheme::Coded, ..Default::default() };
    let clean = try_run_cluster_on(job, &cfg, iters, TransportKind::InProc).expect("clean run");
    let committed = iters / 2;
    let half =
        try_run_cluster_on(job, &cfg, committed, TransportKind::InProc).expect("half run");
    let spec = JobSpec {
        graph: GraphSpec { kind: GraphKind::Er { p }, n, seed: 4242 },
        alloc: AllocKind::Er,
        k,
        r,
        program: ProgramSpec::PageRank,
        scheme: Scheme::Coded,
        iters,
    };
    let ck = Checkpoint { spec, iter: committed, epoch: 0, state: half.final_state };
    let path = std::env::temp_dir().join("coded-graph-bench-ckpt.json");
    let bench = if smoke { Bench::new(1, 3) } else { Bench::new(2, 6) };
    let m_write = bench.run(|| ck.write(&path).expect("checkpoint write"));
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let m_read = bench.run(|| Checkpoint::read(&path).expect("checkpoint read"));
    std::fs::remove_file(&path).ok();

    let opts = RunOpts { warm: Some(ck.state.clone()), ..Default::default() };
    let t0 = std::time::Instant::now();
    let resumed =
        try_run_cluster_on_with(job, &cfg, iters - committed, TransportKind::InProc, &opts)
            .expect("resume run");
    let resume_wall_s = t0.elapsed().as_secs_f64();
    assert!(
        clean
            .final_state
            .iter()
            .zip(&resumed.final_state)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "resume must land bit-identical to the uninterrupted run"
    );

    println!("# Checkpoint/resume: ER(n={n}, p={p}), K={k}, r={r}, committed iter {committed}\n");
    println!(
        "checkpoint write: {:.3} ms   read: {:.3} ms   file {:.1} KiB   resume ({} iters): {:.1} ms",
        m_write.mean_ms(),
        m_read.mean_ms(),
        file_bytes as f64 / 1024.0,
        iters - committed,
        resume_wall_s * 1e3,
    );
    println!("(resume warm-start is bit-identical to the uninterrupted run — asserted here)\n");
    report.record(
        "checkpoint_resume",
        &[
            ("n", num(n as f64)),
            ("p", num(p)),
            ("k", num(k as f64)),
            ("r", num(r as f64)),
            ("iters", num(iters as f64)),
            ("committed_iter", num(committed as f64)),
            ("write_mean_s", num(m_write.mean_s)),
            ("read_mean_s", num(m_read.mean_s)),
            ("file_bytes", num(file_bytes as f64)),
            ("resume_wall_s", num(resume_wall_s)),
        ],
    );
}

/// The PR 10 pipelined fabric at the ISSUE-10 pin (K=10, r=3): the same
/// coded TCP cluster job under `--fabric sync` vs `--fabric pipelined`,
/// recording total and median per-iteration wall time plus the transport
/// counters (`data_frames` staged, `batched_writes` physically
/// completed). Under the sync fabric the worker thread blocks inside
/// `flush()` for the whole wire time of its own sends; under the
/// pipelined fabric that flush runs on the writer thread while the
/// worker ingests, decodes, and encodes the next iteration — so the
/// pipelined per-iteration wall must come in at or below sync's
/// (asserted with slack by `make bench-smoke`; the raw numbers are the
/// record). The final states of both runs are asserted bit-identical
/// here: overlap moves wire time, never bits.
fn overlap(smoke: bool, report: &mut BenchJson) {
    let (n, p) = if smoke { (600usize, 0.06f64) } else { (2000, 0.05) };
    let (k, r) = (10usize, 3usize);
    let iters = if smoke { 4usize } else { 8 };
    let g = er(n, p, &mut DetRng::seed(8181));
    let prog = PageRank::default();
    let alloc = Allocation::er_scheme(n, k, r);
    let job = Job { graph: &g, alloc: &alloc, program: &prog };
    let prep = prepare(&job, Scheme::Coded);
    let caps = mesh_ring_capacities(&prep, k);

    let run_fabric = |fabric: FabricKind, depth: usize| -> Option<(JobReport, usize, f64)> {
        let net = match TcpNet::new(&caps) {
            Ok(net) => net,
            Err(e) => {
                println!("# Fabric overlap: skipped (no localhost sockets: {e})");
                return None;
            }
        };
        let cfg = EngineConfig {
            scheme: Scheme::Coded,
            fabric,
            pipeline_depth: depth,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let rep = run_cluster_net(&job, &cfg, iters, &net, &RunOpts::default());
        let wall_s = t0.elapsed().as_secs_f64();
        Some((rep, net.data_stats().batched_writes, wall_s))
    };
    let median_iter_wall = |rep: &JobReport| -> f64 {
        let mut walls: Vec<f64> = rep.iterations.iter().map(|m| m.wall_s).collect();
        walls.sort_by(|a, b| a.partial_cmp(b).expect("finite wall times"));
        walls[walls.len() / 2]
    };

    let Some((rep_sync, writes_sync, wall_sync)) = run_fabric(FabricKind::Sync, 1) else {
        return;
    };
    let Some((rep_pipe, writes_pipe, wall_pipe)) = run_fabric(FabricKind::Pipelined, 1) else {
        return;
    };
    assert!(
        rep_sync
            .final_state
            .iter()
            .zip(&rep_pipe.final_state)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "pipelined fabric must be bit-identical to sync"
    );
    let frames: usize = rep_sync.iterations.iter().map(|m| m.shuffle.messages).sum();
    let med_sync = median_iter_wall(&rep_sync);
    let med_pipe = median_iter_wall(&rep_pipe);

    println!("# Fabric overlap: coded TCP cluster, ER(n={n}, p={p}), K={k}, r={r}, {iters} iters\n");
    println!(
        "sync:      wall {:.1} ms   median iter {:.2} ms   {writes_sync} flush writes",
        wall_sync * 1e3,
        med_sync * 1e3,
    );
    println!(
        "pipelined: wall {:.1} ms   median iter {:.2} ms   {writes_pipe} flush writes   {:.2}x iter",
        wall_pipe * 1e3,
        med_pipe * 1e3,
        med_sync / med_pipe,
    );
    println!("(final states bit-identical — asserted here; `make bench-smoke` pins");
    println!(" pipelined median iter wall <= sync's with 10% slack)\n");
    for (fabric, writes, wall_s, med) in [
        ("sync", writes_sync, wall_sync, med_sync),
        ("pipelined", writes_pipe, wall_pipe, med_pipe),
    ] {
        report.record(
            "overlap",
            &[
                ("n", num(n as f64)),
                ("p", num(p)),
                ("k", num(k as f64)),
                ("r", num(r as f64)),
                ("iters", num(iters as f64)),
                ("fabric", Json::Str(fabric.into())),
                ("pipeline_depth", num(1.0)),
                ("wall_s", num(wall_s)),
                ("iter_wall_median_s", num(med)),
                ("data_frames", num(frames as f64)),
                ("batched_writes", num(writes as f64)),
            ],
        );
    }
}

/// The TCP batched wire path: the same frame stream sent with one
/// syscall per frame vs staged and flushed with one buffered write per
/// destination — the syscall cost the cluster's Shuffle sheds.
fn tcp_batching(smoke: bool, report: &mut BenchJson) {
    let frames = if smoke { 512usize } else { 4096 };
    let r = 3usize;
    let sb = seg_bytes(r);
    let cols = vec![0x5AA5_5AA5_5AA5_5AA5u64 & ((1u64 << (sb * 8)) - 1); 16];
    let net = match TcpNet::new(&[frames + 8, frames + 8]) {
        Ok(net) => net,
        Err(e) => {
            println!("# TCP batching: skipped (no localhost sockets: {e})");
            return;
        }
    };
    let mut buf = Vec::new();
    let mut rbuf = Vec::new();

    let (_, per_frame_s) = Bench::once(|| {
        for i in 0..frames {
            frame::encode_coded(&mut buf, 0, i as u32, &cols, sb);
            net.send_unicast(0, 1, &buf);
        }
        for _ in 0..frames {
            assert!(net.recv(1, &mut rbuf));
        }
    });
    let (_, batched_s) = Bench::once(|| {
        for i in 0..frames {
            frame::encode_coded(&mut buf, 0, i as u32, &cols, sb);
            net.send_unicast_buffered(0, 1, &buf);
        }
        net.flush(0);
        for _ in 0..frames {
            assert!(net.recv(1, &mut rbuf));
        }
    });
    let writes = net.data_stats().batched_writes;

    println!("# TCP batched wire path: {frames} coded frames to one peer\n");
    println!(
        "per-frame writes: {:.2} ms ({frames} syscalls)   batched: {:.2} ms ({writes} flush write{})   {:.1}x",
        per_frame_s * 1e3,
        batched_s * 1e3,
        if writes == 1 { "" } else { "s" },
        per_frame_s / batched_s,
    );
    report.record(
        "tcp_send_per_frame",
        &[("frames", num(frames as f64)), ("mean_s", num(per_frame_s))],
    );
    report.record(
        "tcp_send_batched",
        &[
            ("frames", num(frames as f64)),
            ("mean_s", num(batched_s)),
            ("batched_writes", num(writes as f64)),
        ],
    );
}
