//! Bench: Shuffle hot-path microbenchmarks — the §Perf workhorse.
//!
//! Measures, per computation load r:
//!   * group-plan construction (pre-processing, O(m)),
//!   * coded Encode throughput (table XOR, bytes/s),
//!   * coded Decode throughput (cancel + reassemble, bytes/s),
//!   * uncoded transfer planning,
//! on a dense mid-size ER graph so the tables are large enough to measure.
//!
//! ```sh
//! cargo bench --bench shuffle_micro
//! ```

use coded_graph::allocation::Allocation;
use coded_graph::graph::er::er;
use coded_graph::mapreduce::{PageRank, VertexProgram};
use coded_graph::shuffle::coded::{encode_group, row_values};
use coded_graph::shuffle::decoder::recover_group_shared;
use coded_graph::shuffle::plan::build_group_plans;
use coded_graph::shuffle::segments::seg_bytes;
use coded_graph::shuffle::uncoded::plan_uncoded;
use coded_graph::util::benchkit::{Bench, Table};
use coded_graph::util::rng::DetRng;
use coded_graph::Vertex;

fn main() {
    let (n, p, k) = (3000usize, 0.1f64, 6usize);
    let g = er(n, p, &mut DetRng::seed(123));
    println!("# Shuffle micro-benchmarks: ER(n={n}, p={p}), K={k}, m={}\n", g.m());
    let prog = PageRank::default();
    let state: Vec<f64> = (0..n as Vertex).map(|v| prog.init(v, &g)).collect();
    let bench = Bench::new(1, 5);

    let mut t = Table::new(&[
        "r", "plan (ms)", "ivs", "encode (ms)", "enc MB/s", "decode (ms)", "dec MB/s", "uncoded plan (ms)",
    ]);
    for r in 2..k {
        let alloc = Allocation::er_scheme(n, k, r);
        let m_plan = bench.run(|| build_group_plans(&g, &alloc));
        let plans = build_group_plans(&g, &alloc);
        let total_ivs: usize = plans.iter().map(|p| p.total_ivs()).sum();
        let value = |i: Vertex, j: Vertex| prog.map(i, j, state[j as usize], &g).to_bits();

        // encode: all groups, all senders
        let m_enc = bench.run(|| {
            let mut cols = 0usize;
            for plan in &plans {
                for msg in encode_group(plan, &value, r) {
                    cols += msg.columns.len();
                }
            }
            cols
        });
        // table bytes XORed per full encode: every row appears in r tables
        let enc_bytes = total_ivs * seg_bytes(r) * r;

        // decode: every member of every group (engine path: row values
        // shared between the encoder and all receivers)
        let m_dec = bench.run(|| {
            let mut recovered = 0usize;
            for plan in &plans {
                let vals = row_values(plan, &value);
                let msgs: Vec<_> = (0..plan.servers.len())
                    .map(|s| coded_graph::shuffle::coded::encode_sender(plan, s, &vals, r))
                    .collect();
                for m_idx in 0..plan.servers.len() {
                    recovered +=
                        recover_group_shared(plan, m_idx, &msgs, &vals, r).len();
                }
            }
            recovered
        });
        let dec_bytes = total_ivs * seg_bytes(r) * r; // segments recovered

        let m_unc = bench.run(|| plan_uncoded(&g, &alloc));

        t.row(&[
            r.to_string(),
            format!("{:.2}", m_plan.mean_ms()),
            total_ivs.to_string(),
            format!("{:.2}", m_enc.mean_ms()),
            format!("{:.0}", enc_bytes as f64 / m_enc.mean_s / 1e6),
            format!("{:.2}", m_dec.mean_ms()),
            format!("{:.0}", dec_bytes as f64 / m_dec.mean_s / 1e6),
            format!("{:.2}", m_unc.mean_ms()),
        ]);
    }
    t.print();
    println!("\nnote: decode re-derives r-1 foreign segments per own segment, so its");
    println!("byte throughput is inherently ~1/r of encode's on the same table.");
}
