//! Bench: regenerate **paper Fig 2 and Fig 7a–c** — PageRank execution
//! time vs computation load for the three EC2 scenarios, with the paper's
//! Map / Shuffle / Reduce bars (Encode folded into Map, Decode into
//! Reduce, as in the paper's footnote 1) and the Remark-10 r* heuristic.
//!
//! Default runs are linearly scaled down (n/scale, same density) so the
//! bench completes in minutes; set `CODED_GRAPH_FULL=1` for the paper's
//! exact sizes. Scaling shrinks absolute seconds but preserves the
//! per-r shape — who wins and where the optimum lands.
//!
//! ```sh
//! cargo bench --bench fig7_scenarios
//! CODED_GRAPH_FULL=1 cargo bench --bench fig7_scenarios   # paper sizes
//! ```

use coded_graph::analysis::theory;
use coded_graph::experiments::scenarios::{
    run_scenario_scaled, scenario, speedup_over_naive,
};
use coded_graph::util::benchkit::{Bench, Table};

fn main() {
    let full = std::env::var("CODED_GRAPH_FULL").is_ok();
    // paper-reported best speedups for the shape check
    let paper = [(1usize, 43.4f64, 5usize), (2, 50.8, 4), (3, 41.8, 4)];
    for (id, paper_speedup, paper_best_r) in paper {
        let scale = if full {
            1
        } else {
            match id {
                1 => 4,  // n = 17,340
                2 => 4,  // n = 3,150 (p = 0.3 keeps it dense)
                _ => 4,  // n = 22,522
            }
        };
        let sc = scenario(id, scale);
        println!("\n# Scenario {id}: {} — n={}, K={} (scale 1/{scale})", sc.name, sc.n, sc.k);
        let (rows, secs) = Bench::once(|| run_scenario_scaled(&sc, 7 + id as u64, scale));
        let mut t = Table::new(&[
            "r", "scheme", "Map(+enc)", "Shuffle", "Reduce(+dec+upd)", "Total", "norm-load",
        ]);
        for row in &rows {
            let (m, s, rd) = row.times.paper_buckets();
            t.row(&[
                row.r.to_string(),
                row.scheme.to_string(),
                format!("{m:.2}s"),
                format!("{s:.2}s"),
                format!("{rd:.2}s"),
                format!("{:.2}s", row.total_s),
                format!("{:.5}", row.load),
            ]);
        }
        t.print();
        let (best_r, speedup) = speedup_over_naive(&rows);
        let naive = &rows[0];
        let (nm, ns, _) = naive.times.paper_buckets();
        println!(
            "best r = {best_r} -> {:.1}% speedup over naive   (paper: {paper_speedup:.1}% at r = {paper_best_r})",
            speedup * 100.0
        );
        println!(
            "Remark 10: r* = sqrt(T_shuffle/T_map) = {:.2} (paper Scenario 2: 5.15)",
            theory::r_star(nm, ns)
        );
        println!("[{secs:.1}s]");
    }
    println!("\nshape checks: Shuffle dominates at r=1; coding slashes Shuffle ~1/r;");
    println!("Map grows ~linearly in r; optimum r in the middle — as in Fig 7.");
}
