# coded-graph — build / test / bench entry points.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-smoke cluster-smoke fmt clippy artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Full figure-reproduction benches (minutes).
bench:
	$(CARGO) bench

# Tiny bench config to catch perf-harness bitrot in CI (seconds).
bench-smoke:
	$(CARGO) bench --bench shuffle_micro -- --smoke

# End-to-end cluster run over real localhost sockets (seconds): a small
# ER PageRank job through the TCP transport, leader + 4 workers.
cluster-smoke:
	$(CARGO) run --release -- cluster --graph er --n 600 --k 4 --r 2 \
	  --program pagerank --scheme coded --iters 2 --transport tcp

# AOT-lower the JAX/Pallas kernels to HLO text for the PJRT runtime
# (build-time only; requires jax — see python/compile/aot.py).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts
