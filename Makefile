# coded-graph — build / test / bench entry points.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-smoke bench-compare bench-snapshot cluster-smoke sim-smoke examples docs fmt clippy artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Full figure-reproduction benches (minutes).
bench:
	$(CARGO) bench

# Tiny bench config to catch perf-harness bitrot in CI (seconds); also
# emits the machine-readable perf trajectory CI parses and archives.
# (cargo bench runs the harness with CWD at the package root, so the
# JSON path is anchored to the invocation directory explicitly)
# The trailing checks assert the degraded-mode `recovery` section made it
# into the document (failure-free row reports zero inflation) and that
# the `observer_overhead` section landed under the ISSUE-7 5% tracing
# budget.
bench-smoke:
	$(CARGO) bench --bench shuffle_micro -- --smoke --json $(CURDIR)/BENCH_shuffle_micro.json
	$(PYTHON) -c "import json; \
	recs = [r for r in json.load(open('$(CURDIR)/BENCH_shuffle_micro.json'))['records'] if r['bench'] == 'recovery']; \
	assert {int(r['failures']) for r in recs} == {0, 1, 2}, recs; \
	assert all(r['recovered_groups'] > 0 for r in recs if r['failures'] > 0), recs; \
	clean = [r for r in recs if r['failures'] == 0]; \
	assert clean and clean[0]['load_inflation'] == 0.0, recs; \
	print(f'recovery section: {len(recs)} records ok')"
	$(PYTHON) -c "import json; \
	recs = [r for r in json.load(open('$(CURDIR)/BENCH_shuffle_micro.json'))['records'] if r['bench'] == 'observer_overhead']; \
	assert len(recs) == 1, recs; \
	r = recs[0]; \
	assert r['traced_mean_s'] > 0 and r['untraced_mean_s'] > 0, r; \
	assert r['overhead'] < 0.05, f\"flight recorder overhead {r['overhead']:.2%} breaks the 5% budget\"; \
	print(f\"observer overhead: {r['overhead']:+.2%} (budget 5%) ok\")"
	$(PYTHON) -c "import json; \
	recs = [r for r in json.load(open('$(CURDIR)/BENCH_shuffle_micro.json'))['records'] if r['bench'] == 'overlap']; \
	by = {r['fabric']: r for r in recs}; \
	assert set(by) == {'sync', 'pipelined'}, recs; \
	s, p = by['sync'], by['pipelined']; \
	assert s['data_frames'] == p['data_frames'] > 0, (s, p); \
	assert p['batched_writes'] > 0, p; \
	assert p['iter_wall_median_s'] <= s['iter_wall_median_s'] * 1.10, \
	  f\"pipelined median iter {p['iter_wall_median_s']*1e3:.2f} ms exceeds sync {s['iter_wall_median_s']*1e3:.2f} ms + 10% slack\"; \
	print(f\"overlap: pipelined {p['iter_wall_median_s']*1e3:.2f} ms vs sync {s['iter_wall_median_s']*1e3:.2f} ms per iter ok\")"

# Diff the current bench-smoke output against the committed per-PR
# snapshot (benches/snapshots/). Non-fatal by design: CI runs it with
# continue-on-error so a perf swing is visible in the log, not a gate.
bench-compare:
	$(PYTHON) tools/bench_compare.py $(CURDIR)/BENCH_shuffle_micro.json benches/snapshots/BENCH_shuffle_micro.json

# Refresh the committed snapshot from the current machine's bench-smoke
# output (run bench-smoke first; commit the result with the PR).
bench-snapshot:
	cp $(CURDIR)/BENCH_shuffle_micro.json benches/snapshots/BENCH_shuffle_micro.json

# End-to-end cluster runs over real localhost sockets (seconds):
#  1) a small ER PageRank job through the threaded TCP mesh;
#  2) the same job as REAL separate OS processes (leader spawns workers,
#     bootstrap rendezvous distributes the roster + job spec) with
#     --check asserting final states bit-identical to the engine;
#  3) a process-separated run that loses worker 2 at iteration 1 and must
#     recover onto the surviving replicas, still bit-identical (--check);
#  4) the adopter cascade: worker 1 dies at iteration 1, then worker 0 —
#     the adopter elected for it — dies at iteration 2; r=3 tolerates
#     both, chaining two recovery epochs, still bit-identical (--check);
#  5) checkpoint → kill past tolerance → resume: the first run aborts
#     typed (hence the leading `!`) but leaves a committed-state
#     checkpoint; the --resume run warm-starts a fresh mesh from it and
#     --check pins the final state to the full-length engine oracle;
#  6) the pipelined fabric (PR 10): the same TCP job over the
#     double-buffered non-blocking wire path, clean and with a worker
#     killed mid-job — --check pins both bit-identical to the engine.
cluster-smoke:
	$(CARGO) run --release -- cluster --graph er --n 600 --k 4 --r 2 \
	  --program pagerank --scheme coded --iters 2 --transport tcp
	$(CARGO) run --release -- cluster --graph er --n 400 --k 2 --r 2 \
	  --program pagerank --scheme coded --iters 2 --transport tcp \
	  --processes --check
	$(CARGO) run --release -- cluster --graph er --n 400 --k 2 --r 2 \
	  --program pagerank --scheme uncoded --iters 2 --transport tcp \
	  --processes --check
	$(CARGO) run --release -- cluster --graph er --n 400 --k 3 --r 2 \
	  --program pagerank --scheme coded --iters 3 --transport tcp \
	  --processes --check --fail-worker 2@1
	$(CARGO) run --release -- cluster --graph er --n 400 --k 4 --r 3 \
	  --program pagerank --scheme coded --iters 3 --transport tcp \
	  --processes --check --fail-worker 1@1,0@2
	! $(CARGO) run --release -- cluster --graph er --n 400 --k 4 --r 2 \
	  --program pagerank --scheme coded --iters 3 --transport tcp \
	  --fail-worker 1@1,3@2 \
	  --checkpoint $(CURDIR)/cluster_ckpt.json --checkpoint-every 1
	$(CARGO) run --release -- cluster --resume $(CURDIR)/cluster_ckpt.json \
	  --transport tcp --check
	rm -f $(CURDIR)/cluster_ckpt.json
	$(CARGO) run --release -- cluster --graph er --n 600 --k 4 --r 2 \
	  --program pagerank --scheme coded --iters 2 --transport tcp \
	  --fabric pipelined --pipeline-depth 2 --check
	$(CARGO) run --release -- cluster --graph er --n 400 --k 4 --r 3 \
	  --program pagerank --scheme coded --iters 3 --transport tcp \
	  --fabric pipelined --check --fail-worker 2@1

# SimFabric smoke (seconds): a tiny sim-sweep (two K × r points on both
# graph models plus the K=8 failure-policy replay at f=1 and the f=2
# adopter cascade) emitting the same Fig-5-style JSON the full-scale
# sweep produces, gated by a json.tool round-trip; then the PR-8
# acceptance check — two same-seed `simulate` runs at K=512 must emit
# byte-identical JSON, under both straggler distributions (the lognormal
# pair also exercises the PR-9 `--straggler-dist` path).
sim-smoke:
	$(CARGO) run --release -- sim-sweep --ks 8,16 --rs 2 --n-min 256 --n-max 256 \
	  --trials 2 --fail-k 8 --json $(CURDIR)/BENCH_sim_sweep.json
	$(PYTHON) -m json.tool $(CURDIR)/BENCH_sim_sweep.json > /dev/null
	$(CARGO) run --release -- simulate --graph er --n 1024 --k 512 --r 3 --iters 2 \
	  --straggler-prob 0.25 --json $(CURDIR)/sim_replay_a.json
	$(CARGO) run --release -- simulate --graph er --n 1024 --k 512 --r 3 --iters 2 \
	  --straggler-prob 0.25 --json $(CURDIR)/sim_replay_b.json
	cmp $(CURDIR)/sim_replay_a.json $(CURDIR)/sim_replay_b.json
	$(CARGO) run --release -- simulate --graph er --n 1024 --k 512 --r 3 --iters 2 \
	  --straggler-prob 0.25 --straggler-dist lognormal --json $(CURDIR)/sim_replay_a.json
	$(CARGO) run --release -- simulate --graph er --n 1024 --k 512 --r 3 --iters 2 \
	  --straggler-prob 0.25 --straggler-dist lognormal --json $(CURDIR)/sim_replay_b.json
	cmp $(CURDIR)/sim_replay_a.json $(CURDIR)/sim_replay_b.json
	rm -f $(CURDIR)/sim_replay_a.json $(CURDIR)/sim_replay_b.json

# Build every example, then run the two that pin the public API surface
# (quickstart's 60-second tour and the end-to-end e2e driver — the
# latter runs the exact rust Reduce unless built with --features xla).
examples:
	$(CARGO) build --release --examples
	$(CARGO) run --release --example quickstart
	$(CARGO) run --release --example coded_pagerank_e2e

# Docs must build warning-clean (broken links, private-item links, bad
# HTML in rustdoc all fail CI).
docs:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# AOT-lower the JAX/Pallas kernels to HLO text for the PJRT runtime
# (build-time only; requires jax — see python/compile/aot.py).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts
