# coded-graph — build / test / bench entry points.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-smoke fmt clippy artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Full figure-reproduction benches (minutes).
bench:
	$(CARGO) bench

# Tiny bench config to catch perf-harness bitrot in CI (seconds).
bench-smoke:
	$(CARGO) bench --bench shuffle_micro -- --smoke

# AOT-lower the JAX/Pallas kernels to HLO text for the PJRT runtime
# (build-time only; requires jax — see python/compile/aot.py).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts
